"""EcoFreq (Alg. 1) semantics + baseline controllers."""
import numpy as np
import pytest

from repro.configs.registry import REGISTRY
from repro.core.ecofreq import (
    BatchInfo,
    EcoFreq,
    IntervalFreq,
    PowerCapFreq,
    StaticFreq,
    SystemState,
)
from repro.core.ecopred import EcoPred
from repro.core.hwmodel import HardwareModel
from repro.core.power import A100
from repro.core import power as P


@pytest.fixture(scope="module")
def pred():
    hw = HardwareModel(REGISTRY["llama-3.1-8b"], A100)
    return EcoPred(A100.freq_levels_5).offline_profile(
        hw, n_prefill=1200, n_decode=3000, noise_sigma=0.0
    )


@pytest.fixture(scope="module")
def ef(pred):
    return EcoFreq(A100.freq_levels_5, pred, slo_ttft_s=0.6, slo_itl_s=0.06)


def test_queue_check_forces_max(ef):
    """Alg. 1 step ①: any waiting request ⇒ max(F)."""
    b = BatchInfo("decode", n_req=2, n_kv=2000)
    assert ef.select(SystemState(has_waiting=True), b) == max(ef.freq_options)
    assert ef.select(SystemState(has_waiting=False), b) == min(
        ef.freq_options
    )


def test_selection_is_minimum_satisfying(ef, pred):
    """Alg. 1 step ③: the chosen f is the LOWEST option meeting the SLO;
    every lower option violates it."""
    st = SystemState()
    for n_req, n_kv in ((2, 2000), (64, 64000), (300, 450000), (500, 800000)):
        b = BatchInfo("decode", n_req=n_req, n_kv=n_kv)
        f = ef.select(st, b)
        assert f in ef.freq_options
        t = pred.predict_decode(f, n_req, n_kv)[0]
        if f != max(ef.freq_options):
            assert t <= ef.slo_itl_s
        for lower in [x for x in ef.freq_options if x < f]:
            assert pred.predict_decode(lower, n_req, n_kv)[0] > ef.slo_itl_s


def test_prefill_budget_deducts_waiting_time(ef):
    """Eq. 5: S = S_P − max(T_waiting)."""
    st = SystemState()
    relaxed = ef.select(st, BatchInfo("prefill", n_tok=2048,
                                      max_waiting_s=0.0))
    tight = ef.select(st, BatchInfo("prefill", n_tok=2048,
                                    max_waiting_s=0.55))
    assert tight >= relaxed
    assert tight == max(ef.freq_options)


def test_exhausted_budget_returns_max(ef):
    st = SystemState()
    b = BatchInfo("prefill", n_tok=64, max_waiting_s=10.0)
    assert ef.select(st, b) == max(ef.freq_options)


def test_static_and_powercap():
    assert StaticFreq(1005.0).select(SystemState(), BatchInfo("decode")) \
        == 1005.0
    pc = PowerCapFreq(A100, 350.0)
    f = pc.select(SystemState(), BatchInfo("decode"))
    assert P.power(A100, f, 1.0) <= 350.0 + 1.0
    assert f < A100.f_max  # the cap binds


def test_interval_controller_holds_decision(ef):
    ic = IntervalFreq(ef, interval_s=5.0)
    b_small = BatchInfo("decode", n_req=2, n_kv=2000)
    b_big = BatchInfo("decode", n_req=500, n_kv=800000)
    f0 = ic.select(SystemState(now_s=0.0), b_small)
    # load spikes but the window hasn't elapsed: decision held (stale)
    f1 = ic.select(SystemState(now_s=2.0), b_big)
    assert f1 == f0
    f2 = ic.select(SystemState(now_s=6.0), b_big)
    assert f2 == max(ef.freq_options)


def test_straggler_bias_raises_frequency(pred):
    fast = EcoFreq(A100.freq_levels_2, pred, 0.6, 0.06)
    slow = EcoFreq(A100.freq_levels_2, pred, 0.6, 0.06,
                   latency_bias_s=0.05)
    b = BatchInfo("decode", n_req=64, n_kv=64000)
    assert slow.select(SystemState(), b) >= fast.select(SystemState(), b)


def test_powercap_closed_form_across_chip_zoo():
    """Cap invariant + equivalence with the retired 50-step bisection,
    for every chip in the zoo and caps from below-idle to above-max."""
    for chip in P.CHIPS.values():
        for frac in (-0.1, 0.2, 0.45, 0.7, 0.85, 0.97, 1.0, 1.2):
            cap = chip.p_idle + frac * (chip.p_elec_max - chip.p_idle)
            pc = PowerCapFreq(chip, cap)
            assert chip.f_min <= pc.f_cap <= chip.f_max
            # worst-case draw respects the cap wherever it is reachable
            if P.power(chip, chip.f_min, 1.0) <= cap:
                assert P.power(chip, pc.f_cap, 1.0) <= cap
            # reference: the bisection this closed form replaced
            lo, hi = chip.f_min, chip.f_max
            if P.power(chip, hi, 1.0) <= cap:
                ref = hi
            else:
                for _ in range(50):
                    mid = 0.5 * (lo + hi)
                    if P.power(chip, mid, 1.0) <= cap:
                        lo = mid
                    else:
                        hi = mid
                ref = lo
            assert abs(pc.f_cap - ref) < 1e-3, (chip.name, cap)


def test_interval_redecides_exactly_at_boundary(pred):
    """Holds strictly inside the window, re-decides the moment
    ``now - last >= interval_s`` — with the select memo on and off."""
    b_small = BatchInfo("decode", n_req=2, n_kv=2000)
    b_big = BatchInfo("decode", n_req=500, n_kv=800000)
    for memo in (True, False):
        ef2 = EcoFreq(A100.freq_levels_5, pred, 0.6, 0.06,
                      select_memo=memo)
        ic = IntervalFreq(ef2, interval_s=5.0)
        f0 = ic.select(SystemState(now_s=0.0), b_small)
        assert ic.select(SystemState(now_s=4.999), b_big) == f0
        assert ic.select(SystemState(now_s=5.0), b_big) \
            == max(ef2.freq_options)


def test_interval_invalidate_forwards_but_keeps_held(pred):
    """invalidate() drops the wrapped EcoFreq's memo yet keeps the held
    window decision — dropping it would re-decide off-boundary and
    diverge from a memo-disabled run."""
    ef2 = EcoFreq(A100.freq_levels_5, pred, 0.6, 0.06, select_memo=True)
    ic = IntervalFreq(ef2, interval_s=5.0)
    b_small = BatchInfo("decode", n_req=2, n_kv=2000)
    f0 = ic.select(SystemState(now_s=0.0), b_small)
    assert ef2._memo, "select never populated the memo"
    ic.invalidate()
    assert not ef2._memo
    b_big = BatchInfo("decode", n_req=500, n_kv=800000)
    assert ic.select(SystemState(now_s=1.0), b_big) == f0


def test_interval_with_memo_matches_memoless_twin(pred):
    """IntervalFreq over a memoized EcoFreq replays bit-identically to
    one over a memo-disabled EcoFreq across a random state sweep."""
    em = IntervalFreq(
        EcoFreq(A100.freq_levels_5, pred, 0.6, 0.06, select_memo=True),
        interval_s=2.0,
    )
    eu = IntervalFreq(
        EcoFreq(A100.freq_levels_5, pred, 0.6, 0.06, select_memo=False),
        interval_s=2.0,
    )
    rng = np.random.default_rng(7)
    t = 0.0
    for _ in range(300):
        t += float(rng.uniform(0.05, 0.9))
        b = BatchInfo("decode", n_req=int(rng.integers(1, 500)),
                      n_kv=int(rng.integers(100, 800000)))
        st = SystemState(now_s=t, has_waiting=bool(rng.random() < 0.1))
        assert em.select(st, b) == eu.select(st, b)
    # two boundary crossings with one identical state: the second base
    # re-decision must come from the memo
    b = BatchInfo("decode", n_req=8, n_kv=5000)
    hits0 = em.base.select_memo_hits
    for dt in (3.0, 6.0):
        st = SystemState(now_s=t + dt)
        assert em.select(st, b) == eu.select(st, b)
    assert em.base.select_memo_hits > hits0
