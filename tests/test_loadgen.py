"""Open-loop load-driver regressions: coordinated omission + knees.

The load harness exists to measure saturation honestly; these tests pin
the two ways that goes wrong:

* **Coordinated omission** — a deliberately stalled backend must not
  delay subsequent *arrivals*.  The open-loop driver fires every
  arrival on the trace clock (fire lag identically zero) and the stall
  shows up as queueing latency; the closed-loop foil silently throttles
  its own load and reports near-zero latency for the same scenario.
  The sim cluster itself is checked too: arrival injection times are
  the trace times even when every instance is saturated.
* **Knee detection** — the detected knee tracks true capacity
  monotonically on crafted M/D/1 curves, stays silent on flat curves,
  and the attainment knee finds the last rate holding the floor.
"""
import numpy as np
import pytest

from repro.configs.registry import REGISTRY
from repro.core.power import A100
from repro.serving import (
    ClusterConfig,
    FIFOServer,
    OpenLoopDriver,
    PDCluster,
    SHAREGPT,
    attainment_knee,
    detect_knee,
    poisson_workload,
)
from repro.serving.cluster import build_predictor


def _arrivals(rps=10.0, n=50):
    return [i / rps for i in range(n)]


# ---------------------------------------------------------------------------
# Coordinated omission
# ---------------------------------------------------------------------------


def test_stall_does_not_delay_open_loop_arrivals():
    """The guard the harness exists for: with the server stalled for
    the first 3 s, open-loop fire times stay on the trace clock and the
    stall surfaces as latency."""
    arrivals = _arrivals(rps=10.0, n=40)
    pts = OpenLoopDriver(open_loop=True).run(
        arrivals, FIFOServer(service_s=0.05, stall_until_s=3.0)
    )
    assert all(p.fire_lag_s == 0.0 for p in pts)
    # every request scheduled during the stall eats the remaining stall
    # in its measured latency — nothing is hidden
    lat = [p.latency_s for p in pts]
    assert lat[0] == pytest.approx(3.0 + 0.05)
    assert max(lat) > 1.0


def test_closed_loop_foil_hides_the_stall():
    """Same scenario through the deliberately coordinated driver: fire
    times drift behind the trace clock and the measured latencies
    collapse — the omission the open-loop driver prevents."""
    arrivals = _arrivals(rps=10.0, n=40)
    open_pts = OpenLoopDriver(open_loop=True).run(
        arrivals, FIFOServer(service_s=0.05, stall_until_s=3.0)
    )
    closed_pts = OpenLoopDriver(open_loop=False).run(
        arrivals, FIFOServer(service_s=0.05, stall_until_s=3.0)
    )
    assert max(p.fire_lag_s for p in closed_pts) > 1.0  # load throttled
    # latency measured from *scheduled* time agrees; measured from
    # *fired* time (the classic closed-loop mistake) it vanishes
    fired_lat = [p.done_s - p.fired_s for p in closed_pts[1:]]
    assert max(fired_lat) == pytest.approx(0.05)
    assert np.mean([p.latency_s for p in open_pts]) > 1.0


def test_sim_cluster_is_open_loop():
    """PDCluster injects arrivals at trace times even when saturated:
    offered load 4x a 1P1D fleet's capacity must not shift any
    request's arrival_s (arrivals are heap events, never gated on
    completions)."""
    model = REGISTRY["llama-3.1-8b"]
    pred = build_predictor(model, A100, A100.freq_levels_2,
                           kv_cap=200_000)
    reqs = poisson_workload(SHAREGPT, 60.0, 20.0, seed=0)
    scheduled = [r.arrival_s for r in reqs]
    cfg = ClusterConfig(
        model=model, chip=A100, n_prefill=1, n_decode=1,
        predictor=pred, kv_capacity_tokens=200_000,
        online_adapt=False, seed=0,
    )
    m = PDCluster(cfg).run(reqs)
    assert [r.arrival_s for r in reqs] == scheduled
    # saturation is visible as queueing, not as missing load
    assert m.finished_frac() == 1.0
    assert float(np.quantile(m.ttft_values(), 0.99)) > 1.0


def test_driver_validates_input():
    with pytest.raises(ValueError, match="sorted"):
        OpenLoopDriver().run([1.0, 0.5], FIFOServer(0.01))
    with pytest.raises(ValueError, match="before"):
        OpenLoopDriver().run([0.0], lambda rid, t: t - 1.0)


# ---------------------------------------------------------------------------
# Knee detection
# ---------------------------------------------------------------------------


def _mdo_latency(rates, mu):
    """Open-loop queueing-wait curve with capacity ``mu``: M/M/1-style
    blow-up approaching mu, then linear backlog growth past it (an
    open-loop queue keeps absorbing arrivals beyond capacity)."""
    return [
        1.0 / (mu - r + 0.5) if r < mu else 2.0 + (r - mu)
        for r in rates
    ]


def test_knee_monotone_in_capacity():
    """Crafted saturating curves: higher true capacity -> knee detected
    at a higher (or equal) rate, strictly higher across the range."""
    rates = [float(r) for r in range(2, 42, 2)]
    knees = [
        detect_knee(rates, _mdo_latency(rates, mu))
        for mu in (5.0, 10.0, 20.0)
    ]
    assert all(k is not None for k in knees)
    assert knees == sorted(knees)
    assert knees[-1] > knees[0]


def test_knee_none_on_flat_curve():
    rates = [2.0, 4.0, 6.0, 8.0]
    assert detect_knee(rates, [0.10, 0.11, 0.10, 0.105]) is None


def test_knee_input_validation():
    with pytest.raises(ValueError):
        detect_knee([1.0, 2.0], [0.1, 0.2])  # too few points
    with pytest.raises(ValueError):
        detect_knee([1.0, 1.0, 2.0], [0.1, 0.2, 0.3])  # non-increasing


def test_attainment_knee():
    rates = [2.0, 4.0, 6.0, 8.0, 10.0]
    assert attainment_knee(rates, [1.0, 0.99, 0.95, 0.6, 0.3]) == 6.0
    # floor never lost inside the sweep: knee is beyond it
    assert attainment_knee(rates, [1.0] * 5) is None
    # floor never met at all
    assert attainment_knee(rates, [0.5] * 5) is None
