"""GBDT library: fit quality, online continuation, packed-predict
equivalence (property), staircase capture."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.gbdt import GBLinear, GBTree


@pytest.fixture(scope="module")
def staircase_data():
    rng = np.random.default_rng(0)
    n = 5000
    X = np.stack([rng.uniform(1, 512, n), rng.uniform(0, 1e6, n)], 1)
    y = 0.002 * np.ceil(X[:, 0] / 128) * 128 + 1e-8 * X[:, 1] + 0.005
    return X, y


def test_gblinear_fits_linear_target():
    rng = np.random.default_rng(1)
    X = rng.uniform(0, 1, (2000, 2))
    y = 3.0 * X[:, 0] + 0.5 * X[:, 1] + 0.1
    m = GBLinear().fit(X, y)
    assert np.abs(m.predict(X) - y).mean() < 1e-3


def test_gblinear_continue_fit_tracks_shift():
    rng = np.random.default_rng(2)
    X = rng.uniform(0, 1, (2000, 2))
    y = 2.0 * X[:, 0] + 0.2
    m = GBLinear().fit(X, y)
    y2 = y + 0.5  # shifted online distribution
    before = np.abs(m.predict(X) - y2).mean()
    m.continue_fit(X, y2)
    after = np.abs(m.predict(X) - y2).mean()
    assert after < before * 0.2


def test_gbtree_captures_staircase(staircase_data):
    X, y = staircase_data
    m = GBTree(n_estimators=150, learning_rate=0.15).fit(
        X[:4000], y[:4000], eval_set=(X[4000:], y[4000:])
    )
    lo = m.predict(np.array([[250.0, 5e5]]))[0]
    hi = m.predict(np.array([[260.0, 5e5]]))[0]
    true_lo = 0.002 * 256 + 1e-8 * 5e5 + 0.005
    true_hi = 0.002 * 384 + 1e-8 * 5e5 + 0.005
    assert abs(lo - true_lo) < 0.02
    assert abs(hi - true_hi) < 0.02
    assert hi - lo > 0.15  # the cliff is captured


def test_gbtree_packed_predict_matches_per_tree(staircase_data):
    """The level-synchronous packed ensemble must equal tree-by-tree
    evaluation exactly."""
    X, y = staircase_data
    m = GBTree(n_estimators=40, subsample=1.0, colsample=1.0).fit(
        X[:2000], y[:2000]
    )
    B = m._bin(X[:200])
    packed = m.predict_binned(B)
    seq = np.full(200, m.base_)
    for t in m.trees:
        seq += m.learning_rate * t.predict_binned(B)
    np.testing.assert_allclose(packed, seq, rtol=1e-12)


def test_gbtree_continue_fit_improves_on_shift(staircase_data):
    X, y = staircase_data
    m = GBTree(n_estimators=80).fit(X[:4000], y[:4000])
    y_shift = y * 1.15
    before = np.abs(m.predict(X[4000:]) - y_shift[4000:]).mean()
    m.continue_fit(X[:2000], y_shift[:2000], n_more=30)
    after = np.abs(m.predict(X[4000:]) - y_shift[4000:]).mean()
    assert after < before


@given(
    st.integers(10, 200),
    st.integers(1, 4),
    st.integers(0, 10_000),
)
@settings(max_examples=15, deadline=None)
def test_gbtree_predict_finite_on_random_data(n, d, seed):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d))
    y = rng.normal(size=n)
    m = GBTree(n_estimators=10, min_leaf=2).fit(X, y)
    out = m.predict(rng.normal(size=(20, d)))
    assert np.isfinite(out).all()
