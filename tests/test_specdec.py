"""Speculative draft–verify decoding: cost model, variable-yield
scheduling, page-exact rollback, and the bit-exactness contract.

The one-token-per-iteration assumption used to be load-bearing in every
serving layer; these tests pin the refactor's two promises:

* ``spec_decode=False`` is **bit-exact** with pre-speculation main (the
  PR-4 golden energies reproduce to the last ulp);
* ``spec_decode=True`` emits variable yields whose accounting balances
  exactly — tokens, KV growth, acceptance counters, pool refcounts.
"""
import math

import numpy as np
import pytest

from repro.configs.registry import REGISTRY
from repro.core.ecofreq import BatchInfo, EcoFreq, SystemState, expected_emitted
from repro.core.hwmodel import HardwareModel, energy_frequency_curve
from repro.core.power import A100
from repro.serving import ClusterConfig, KVPool, PDCluster, poisson_workload
from repro.serving.kvpool import BlockTable
from repro.serving.workload import SHAREGPT, spec_heterogeneity_workload
from tests._hyp import HAVE_HYPOTHESIS, given, settings, st

MODEL = REGISTRY["llama-3.1-8b"]


# ---------------------------------------------------------------------------
# expected_emitted (the acceptance → yield map every layer shares)
# ---------------------------------------------------------------------------


def test_expected_emitted_values():
    assert expected_emitted(0.0, 4) == 1.0  # nothing accepted: bonus only
    assert expected_emitted(1.0, 4) == 5.0  # everything accepted: k + 1
    assert expected_emitted(0.5, 2) == pytest.approx(1.75)  # 1 + .5 + .25
    assert expected_emitted(0.7, 0) == 1.0  # speculation off


def test_expected_emitted_monotone_in_acceptance():
    k = 4
    vals = [expected_emitted(p, k) for p in np.linspace(0, 1, 21)]
    assert all(b >= a for a, b in zip(vals, vals[1:]))
    assert all(1.0 <= v <= k + 1 for v in vals)


# ---------------------------------------------------------------------------
# Cost model: verify/draft iterations
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def hw():
    return HardwareModel(MODEL, A100)


def test_verify_iter_k0_matches_decode_modulo_kv_write(hw):
    """k=0 verify is a decode step plus the (tiny) single-token KV
    write the legacy decode model omits."""
    d = hw.decode_iter(16, 32_000, A100.f_max)
    v = hw.verify_iter(16, 32_000, 0, A100.f_max)
    assert v.time_s == pytest.approx(d.time_s, rel=2e-3)
    assert v.time_s >= d.time_s  # the write is extra bytes, never less


def test_verify_iter_cheaper_per_token_than_decode(hw):
    """The point of speculation: at memory-bound operating points the
    verify iteration costs far less than k+1 decode iterations."""
    k = 4
    for f in (A100.f_min, A100.f_mem_knee, A100.f_max):
        d = hw.decode_iter(16, 32_000, f)
        v = hw.verify_iter(16, 32_000, k, f)
        assert v.time_s < (k + 1) * d.time_s * 0.6
        assert v.energy_j < (k + 1) * d.energy_j * 0.6


def test_spec_decode_iter_includes_draft_overhead(hw):
    v = hw.verify_iter(16, 32_000, 4, A100.f_max)
    s = hw.spec_decode_iter(16, 32_000, 4, 0.05, A100.f_max)
    d = hw.draft_iter(16, 32_000, 0.05, A100.f_max)
    assert s.time_s == pytest.approx(v.time_s + 5 * d.time_s, rel=1e-9)
    assert s.energy_j == pytest.approx(v.energy_j + 5 * d.energy_j, rel=1e-9)


def test_verify_u_curve_survives(hw):
    """The E(f) curve of a speculative iteration must stay U-shaped:
    an interior sweet spot with both endpoints measurably above it."""
    curve = energy_frequency_curve(
        hw, "verify", n_grid=40, n_req=48, n_kv=96_000, k=4
    )
    e = [r[2] for r in curve]
    i = int(np.argmin(e))
    assert 0 < i < len(e) - 1, "sweet spot pinned to an endpoint"
    assert e[0] > e[i] * 1.02 and e[-1] > e[i] * 1.02


def test_verify_staircases_on_rows_not_requests(hw):
    """MXU tile padding quantizes on n_req*(k+1): the verify staircase
    cliff sits at n_req = tile/(k+1), left of the decode cliff."""
    k = 3
    tile = A100.mxu_tile
    at_tile = hw.verify_iter(tile // (k + 1), 4_000, k, A100.f_max)
    over = hw.verify_iter(tile // (k + 1) + 1, 4_000, k, A100.f_max)
    # crossing the row boundary launches a whole new tile row
    assert over.time_s > at_tile.time_s


# ---------------------------------------------------------------------------
# Page-exact rollback (BlockTable.shrink)
# ---------------------------------------------------------------------------


def test_blocktable_shrink_frees_only_speculative_tail():
    pool = KVPool(16, 4)
    t = BlockTable(pool)
    t.ensure(10)  # 3 pages: covers tokens 0..9
    assert len(t.pages) == 3
    # speculation grows to 10 + k + 1 = 15 -> 4 pages
    t.ensure(15)
    assert len(t.pages) == 4
    # only 2 drafts accepted: roll back to 13 tokens -> still 4 pages
    freed = t.shrink(13)
    assert freed == [] and len(t.pages) == 4
    # nothing accepted: roll back to 11 -> tail page freed
    freed = t.shrink(11)
    assert len(freed) == 1 and len(t.pages) == 3
    assert pool.refcount(freed[0]) == 0
    t.release()
    pool.assert_empty()


def test_blocktable_shrink_never_touches_shared_prefix():
    pool = KVPool(16, 4)
    prefix = pool.alloc(2)  # a radix-held prefix (8 tokens)
    pool.incref(prefix)  # the request's own reference
    t = BlockTable(pool)
    t.adopt(list(prefix), 8)
    t.ensure(8 + 5)  # speculation appends fresh tail pages
    t.shrink(9)  # reject most of the window
    assert all(pool.refcount(p) == 2 for p in prefix)  # untouched
    t.release()
    assert all(pool.refcount(p) == 1 for p in prefix)  # radix ref only
    pool.decref(prefix)
    pool.assert_empty()


# ---------------------------------------------------------------------------
# Variable-yield scheduling (Sim engine invariants)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def bank():
    return {}


def _run(reqs, bank, **kw):
    cfg = ClusterConfig(
        model=MODEL, chip=A100, n_prefill=1, n_decode=2,
        policy="voltana", online_adapt=False, predictor_bank=bank,
        seed=0, paged=True, **kw,
    )
    return PDCluster(cfg).run(reqs)


def test_spec_run_token_accounting_balances(bank):
    reqs = spec_heterogeneity_workload(6.0, 30.0, seed=5)
    m = _run(reqs, bank, spec_decode=True, spec_k=4)
    assert m.finished_frac() == 1.0
    for r in m.requests:
        # every request ends exactly at its stream length
        assert r.tokens_out == r.decode_len
        assert r.kv_len == r.prompt_len + r.decode_len
        # emitted-via-spec = accepted + one bonus per iteration
        assert r.spec_accepted + r.spec_iters == r.tokens_out
        assert r.spec_drafted == 4 * r.spec_iters
        assert 0 <= r.spec_accepted <= r.spec_drafted
    assert 0.0 < m.acceptance_rate() < 1.0
    assert 1.0 <= m.spec_yield() <= 5.0
    assert m.energy_per_accepted_token_j() == pytest.approx(m.epot_j())


def test_spec_yield_tracks_acceptance_heterogeneity(bank):
    """Per-class acceptance must separate: templated requests accept
    more of their drafts than chat requests."""
    reqs = spec_heterogeneity_workload(6.0, 30.0, seed=5)
    m = _run(reqs, bank, spec_decode=True, spec_k=4)

    def cls_rate(kind):
        d = sum(r.spec_drafted for r in m.requests if r.kind == kind)
        a = sum(r.spec_accepted for r in m.requests if r.kind == kind)
        return a / d

    assert cls_rate("templated") > cls_rate("chat") + 0.15


def test_spec_saves_energy_per_token_at_equal_attainment(bank):
    reqs = poisson_workload(SHAREGPT, 5.0, 30.0, seed=3)
    base = _run(reqs, bank, spec_decode=False)
    b_epot = base.energy_per_token_j()
    b_ttft, b_itl = base.ttft_attainment(), base.itl_attainment()
    reqs = poisson_workload(SHAREGPT, 5.0, 30.0, seed=3)
    spec = _run(reqs, bank, spec_decode=True, spec_k=4)
    assert spec.energy_per_token_j() < b_epot
    assert spec.ttft_attainment() >= b_ttft - 1e-9
    assert spec.itl_attainment() >= b_itl - 1e-9


def test_spec_with_tiers_and_preemption(bank):
    """Speculation composes with the tier subsystem: deadline pacing,
    preemption recompute and admission all run over variable yields."""
    from repro.serving import DEFAULT_TIERS
    from repro.serving.workload import tiered_workload

    reqs = tiered_workload(6.0, 30.0, seed=7)
    m = _run(reqs, bank, spec_decode=True, spec_k=4,
             slo_tiers=DEFAULT_TIERS)
    assert m.finished_frac() == 1.0
    for r in m.requests:
        if r.admitted:
            assert r.tokens_out == r.decode_len


def test_spec_sim_is_deterministic(bank):
    """The acceptance realization is a seeded control-plane stream:
    identical configs reproduce identical runs."""
    r1 = spec_heterogeneity_workload(5.0, 20.0, seed=5)
    r2 = spec_heterogeneity_workload(5.0, 20.0, seed=5)
    m1 = _run(r1, bank, spec_decode=True, spec_k=4)
    m2 = _run(r2, bank, spec_decode=True, spec_k=4)
    assert m1.energy_j() == m2.energy_j()
    for a, b in zip(r1, r2):
        assert a.t_finish == b.t_finish
        assert a.spec_accepted == b.spec_accepted


# ---------------------------------------------------------------------------
# Bit-exactness: spec_decode=False vs pre-speculation main (PR-4 pins)
# ---------------------------------------------------------------------------

# captured on PR-4 main (commit 40b9026) with this exact scenario —
# these must reproduce to the last ulp with spec_decode=False
_PR4_GOLDEN = {False: 9563.958314628406, True: 9563.674430277537}


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_spec_off_is_bit_exact_with_pr4_main(paged, bank):
    reqs = poisson_workload(SHAREGPT, 4.0, 30.0, seed=3)
    cfg = ClusterConfig(
        model=MODEL, chip=A100, n_prefill=1, n_decode=2,
        policy="voltana", online_adapt=False, predictor_bank=bank,
        seed=0, paged=paged,
    )
    m = PDCluster(cfg).run(reqs)
    assert m.energy_j() == _PR4_GOLDEN[paged]  # exact, not approx


def test_spec_defaults_are_off():
    assert ClusterConfig.__dataclass_fields__["spec_decode"].default is False
    from repro.serving.engine import DecodeEngine

    assert DecodeEngine.__dataclass_fields__["spec_k"].default == 0


# ---------------------------------------------------------------------------
# EcoFreq pacing under acceptance swings (satellite: property coverage)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def spec_pred():
    from repro.serving.cluster import build_predictor

    return build_predictor(
        MODEL, A100, A100.freq_levels_5, kv_cap=400_000, spec_k=4
    )


def _ef(spec_pred, itl=0.06):
    return EcoFreq(A100.freq_levels_5, spec_pred,
                   slo_ttft_s=0.6, slo_itl_s=itl)


def _pacing_holds(ef, n_req, n_kv, k, p, itl):
    """The Alg.-1 contract over variable yields: the chosen frequency's
    predicted iteration time fits the per-emitted-token budget, or no
    option does and the controller floors it at max(F)."""
    emit = expected_emitted(p, k)
    b = BatchInfo("decode", n_req=n_req, n_kv=n_kv, itl_slo_s=itl,
                  spec_k=k, emitted_per_iter=emit)
    f = ef.select(SystemState(has_waiting=False), b)
    budget = itl * emit
    t = float(ef.predict(np.asarray([f]), b)[0])
    if t <= budget:
        return True
    feasible = ef.predict(np.asarray(ef.freq_options), b) <= budget
    return not feasible.any() and f == max(ef.freq_options)


def test_pacing_grid_acceptance_swing(spec_pred):
    """Always-on grid: pacing holds across the full acceptance range,
    batch sizes, and binding tier ITLs (the hypothesis sweep widens
    this; the grid keeps the invariant exercised without hypothesis)."""
    ef = _ef(spec_pred)
    for p in (0.0, 0.25, 0.5, 0.9, 1.0):
        for n_req, n_kv in ((2, 2_000), (64, 64_000), (256, 300_000)):
            for itl in (0.03, 0.06, 0.12):  # binding tier targets
                assert _pacing_holds(ef, n_req, n_kv, 4, p, itl)


def test_budget_monotone_in_acceptance(spec_pred):
    """A higher acceptance EWMA can only relax the clock (weakly lower
    frequency): E[emitted] is monotone, so the budget is."""
    ef = _ef(spec_pred)
    st_ = SystemState(has_waiting=False)
    for n_req, n_kv in ((16, 20_000), (128, 200_000)):
        prev = None
        for p in np.linspace(0.0, 1.0, 11):
            f = ef.select(st_, BatchInfo(
                "decode", n_req=n_req, n_kv=n_kv, spec_k=4,
                emitted_per_iter=expected_emitted(float(p), 4),
            ))
            if prev is not None:
                assert f <= prev + 1e-9
            prev = f


@pytest.mark.slow
@settings(max_examples=40, deadline=None)
@given(
    p=st.floats(min_value=0.0, max_value=1.0),
    k=st.integers(min_value=1, max_value=8),
    n_req=st.integers(min_value=1, max_value=400),
    kv_per_req=st.integers(min_value=1, max_value=2_000),
    itl_scale=st.floats(min_value=0.5, max_value=6.0),
)
def test_property_pacing_never_misses_binding_itl(
    spec_pred, p, k, n_req, kv_per_req, itl_scale
):
    """Property: for ANY acceptance rate (including mid-run swings to 0
    or 1 — each select() is memoryless in the EWMA argument), draft
    window, batch shape and binding tier ITL, EcoFreq's chosen clock
    fits the per-emitted-token deadline whenever any clock does."""
    ef = _ef(spec_pred)
    itl = 0.06 * itl_scale
    assert _pacing_holds(ef, n_req, n_req * kv_per_req, k, p, itl)


def test_ewma_swing_recovers_pacing(bank):
    """End-to-end: a workload whose acceptance collapses 1→0 mid-run
    (then back) never loses requests and keeps ITL attainment — the
    EWMA follows the swing and the controller re-tightens the clock."""
    reqs = poisson_workload(SHAREGPT, 4.0, 40.0, seed=9)
    for r in reqs:
        third = (r.arrival_s // 13.4) % 3
        r.accept_rate = 0.95 if third != 1 else 0.02
    m = _run(reqs, bank, spec_decode=True, spec_k=4)
    assert m.finished_frac() == 1.0
    assert m.itl_attainment() == 1.0
    assert 0.0 < m.acceptance_rate() < 1.0
