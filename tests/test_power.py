"""Power/energy model: U-curve, TDP wall, and the paper's anchors."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs.registry import REGISTRY
from repro.core import power as P
from repro.core.hwmodel import HardwareModel, energy_frequency_curve, sweet_spot
from repro.core.power import A100, GH200, TPU_V5E


@pytest.fixture(scope="module")
def hw():
    return HardwareModel(REGISTRY["llama-3.1-8b"], A100)


def test_power_monotone_in_frequency():
    for chip in (A100, GH200, TPU_V5E):
        fs = chip.freq_grid(30)
        ps = [P.power(chip, f, 0.8) for f in fs]
        assert all(b >= a for a, b in zip(ps, ps[1:]))


@given(st.floats(0.05, 1.0), st.floats(0.05, 1.0))
@settings(max_examples=30, deadline=None)
def test_power_monotone_in_util(u1, u2):
    f = 1200.0
    p1, p2 = P.power(A100, f, u1), P.power(A100, f, u2)
    assert (p1 <= p2) == (u1 <= u2) or abs(p1 - p2) < 1e-9


def test_tdp_throttle_never_exceeds_cap():
    for f in A100.freq_grid(20):
        fe = P.throttled_frequency(A100, f, 1.0)
        assert P.power(A100, fe, 1.0) <= A100.tdp + 1e-6
        assert fe <= f


def test_latency_monotone_decreasing_in_f(hw):
    curve = energy_frequency_curve(hw, "decode", n_grid=30,
                                   n_req=64, n_kv=64000)
    ts = [t for _, t, _ in curve]
    assert all(b <= a + 1e-12 for a, b in zip(ts, ts[1:]))


def test_u_shape_interior_sweet_spot(hw):
    for phase, st_ in (
        ("prefill", dict(n_tok=4096, avg_ctx=1024)),
        ("decode", dict(n_req=64, n_kv=64000)),
    ):
        f_star = sweet_spot(hw, phase, **st_)
        assert A100.f_min < f_star < A100.f_max
        assert abs(f_star - 1005.0) < 60.0  # paper: 1005 MHz


def test_below_sweet_spot_strictly_worse(hw):
    """Paper Fig. 5: frequencies below the knee raise BOTH energy and
    latency."""
    lo = hw.decode_iter(64, 64000, 700.0)
    knee = hw.decode_iter(64, 64000, 1005.0)
    assert lo.time_s > knee.time_s and lo.energy_j > knee.energy_j


def test_paper_decode_anchor(hw):
    """1005→1410 MHz: ITL ×~0.8, energy ×~1.5 (Fig. 5b)."""
    lo = hw.decode_iter(64, 64000, 1005.0)
    hi = hw.decode_iter(64, 64000, 1410.0)
    assert 0.70 <= hi.time_s / lo.time_s <= 0.88
    assert 1.3 <= hi.energy_j / lo.energy_j <= 1.75


def test_prefill_tdp_wall(hw):
    """Prefill at max frequency throttles to ~1305 MHz (Fig. 5a)."""
    c = hw.prefill_iter(4096, 1024, 1410.0)
    assert 1250.0 <= c.f_effective <= 1350.0


def test_gh200_phase_specific_sweet_spots():
    """Appx. M: prefill sweet ≈1095, decode sweet ≈1395 on GH200."""
    hw = HardwareModel(REGISTRY["qwen3-32b"], GH200)
    sp = sweet_spot(hw, "prefill", n_tok=4096, avg_ctx=1024)
    sd = sweet_spot(hw, "decode", n_req=64, n_kv=64000)
    assert abs(sp - 1095.0) < 120.0
    assert abs(sd - 1395.0) < 120.0
    assert sd > sp  # the decode sweet spot sits higher
