"""Multi-tenant SLO tiers vs the single-tier max-attainment baseline.

VoltanaLLM treats SLO slack as an energy resource, but a single-tier
cluster must pace *every* iteration against the strictest latency target
even when the batch is dominated by lax or best-effort traffic.  This
benchmark serves one diurnal three-class trace (interactive chat /
standard / best-effort bulk — ``tiered_workload``) on the same 2P2D A100
fleet under:

* ``single-tier``  — tiers ignored (``slo_tiers=None``): every request
  is paced, routed, and judged at the strict base SLO — the
  max-attainment baseline;
* ``slo-tiers``    — the full tier subsystem: per-tier SLO targets,
  strict-priority + EDF queueing, tier-aware EcoFreq budgets (tightest
  binding deadline in the batch), tier-aware EcoRoute (interactive
  avoids batch-saturated instances), decode preemption of batch work
  under KV pressure (recompute-on-resume), and admission control that
  sheds best-effort arrivals before interactive SLOs degrade;
* ``slo-tiers[-preempt-admit]`` — ablation (full run only): tiered SLO
  budgets alone, preemption and admission disabled.

Acceptance (pinned by tests/test_golden_smoke.py): >= 10% lower
energy/token than ``single-tier`` at equal-or-better *interactive*
TTFT/ITL attainment, with zero admitted-request loss.

    PYTHONPATH=src python -m benchmarks.run fig_slo_tiers
    BENCH_SMOKE=1 ... (or --smoke)  -> shortened trace for CI
"""
from __future__ import annotations

import os

from benchmarks.common import write_csv
from repro.configs.registry import REGISTRY
from repro.core.power import A100
from repro.serving import (
    DEFAULT_TIERS,
    ClusterConfig,
    PDCluster,
    tiered_workload,
)

MODEL_NAME = "llama-3.1-8b"
SLO_TTFT_S, SLO_ITL_S = 0.6, 0.06  # base == interactive tier (§VI-B)


def _run_one(label, reqs, bank, **cfg_kw):
    cfg = ClusterConfig(
        model=REGISTRY[MODEL_NAME],
        chip=A100,
        n_prefill=2,
        n_decode=2,
        slo_ttft_s=SLO_TTFT_S,
        slo_itl_s=SLO_ITL_S,
        policy="voltana",
        online_adapt=False,
        predictor_bank=bank,
        seed=0,
        **cfg_kw,
    )
    m = PDCluster(cfg).run(reqs)
    row = {"policy": label, "model": MODEL_NAME, **m.summary()}
    for tier, ts in m.tier_summary().items():
        short = {"interactive": "int", "standard": "std", "batch": "bat"}
        k = short.get(tier, tier)
        row[f"{k}_ttft_attain"] = ts["ttft_attain"]
        row[f"{k}_itl_attain"] = ts["itl_attain"]
        row[f"{k}_shed_frac"] = ts["shed_frac"]
        row[f"{k}_energy_share_j"] = ts["energy_share_j"]
    return row, m


def run(out_dir=None):
    smoke = os.environ.get("BENCH_SMOKE") == "1"
    base_rps = 11.0 if smoke else 14.0
    duration = 120.0 if smoke else 300.0
    reqs = tiered_workload(
        base_rps, duration, seed=7,
        interactive_frac=0.40, standard_frac=0.32,
    )
    # 2K chunk budget: bounds the head-of-line stall a bulk prompt can
    # inject ahead of an interactive arrival to one chunk's latency
    # (same granularity for every arm — the comparison stays fair)
    shared = dict(prefill_chunk_tokens=2_048)

    bank = {}
    rows = []
    base_row, base = _run_one(
        "single-tier", reqs, bank, slo_tiers=None, **shared
    )
    rows.append(base_row)
    # snapshot base scalars NOW: RunMetrics aliases the Request objects,
    # which the next arm resets and re-runs
    b_epot, b_energy = base.epot_j(), base.energy_j()
    b_int_ttft = base.ttft_attainment("interactive")
    b_int_itl = base.itl_attainment("interactive")

    arms = [("slo-tiers", dict(slo_tiers=DEFAULT_TIERS))]
    if not smoke:
        arms.append((
            "slo-tiers[-preempt-admit]",
            dict(slo_tiers=DEFAULT_TIERS, preemption=False,
                 admission_control=False),
        ))
    for label, kw in arms:
        row, m = _run_one(label, reqs, bank, **kw, **shared)
        rows.append(row)
        # zero admitted-request loss is a hard contract, not a metric
        assert m.finished_frac() == 1.0, (
            f"{label}: admitted requests lost "
            f"(finished_frac={m.finished_frac()})"
        )
        rows.append({
            "policy": f"delta_vs_single-tier[{label}]",
            "model": MODEL_NAME,
            "epot_saving_frac": round(
                1.0 - m.energy_per_token_j() / b_epot, 4
            ),
            "energy_saving_frac": round(1.0 - m.energy_j() / b_energy, 4),
            "tok_per_j": round(m.tokens_per_joule(), 3),
            "int_ttft_attain_delta": round(
                m.ttft_attainment("interactive") - b_int_ttft, 4
            ),
            "int_itl_attain_delta": round(
                m.itl_attainment("interactive") - b_int_itl, 4
            ),
            "shed_frac": round(m.shed_frac(), 4),
            "preemptions": m.preemptions_total(),
        })
        print(
            f"  {label:26s} vs single-tier: "
            f"energy/tok {m.epot_j()*1e3:7.2f} mJ vs "
            f"{b_epot*1e3:7.2f} mJ "
            f"({100 * (1 - m.epot_j() / b_epot):+.1f}%)  "
            f"int-ttft {m.ttft_attainment('interactive'):.3f} vs "
            f"{b_int_ttft:.3f}  "
            f"int-itl {m.itl_attainment('interactive'):.3f} vs "
            f"{b_int_itl:.3f}  "
            f"shed {m.shed_frac():.3f}  preempt {m.preemptions_total()}"
        )

    write_csv("fig_slo_tiers", rows, out_dir)
    return rows
