"""Benchmark driver: one module per paper figure/table.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig16 fig6 # subset
    PYTHONPATH=src python -m benchmarks.run --quick    # cheap subset
    PYTHONPATH=src python -m benchmarks.run --smoke    # CI smoke (<10 min)

Each module writes ``benchmarks/results/<name>.csv``; this driver prints
a one-line summary per module and a final manifest.  ``--smoke`` also
sets ``BENCH_SMOKE=1`` so serving modules shrink their traces, and
emits ``benchmarks/results/BENCH_serving.json`` — a machine-readable
perf snapshot (event-loop wall time, energy/token, SLO attainment, and
per-module status) that CI uploads so the serving perf trajectory is
comparable across PRs.
"""
from __future__ import annotations

import importlib
import json
import os
import sys
import time
import traceback

MODULES = [
    ("fig1_5_ucurve", "Fig.1/5  U-shaped E-f curves + anchors"),
    ("fig2_3_workload_dynamics", "Fig.2/3  multi-timescale workload dynamics"),
    ("fig4_itl_sensitivity", "Fig.4    decode ITL sensitivity vs batch"),
    ("fig6_staircase", "Fig.6    tile-quantization staircase"),
    ("fig10_predictability", "Fig.10   latency predictability scatter"),
    ("fig13_state_space", "Fig.13   decode state-space freq regions"),
    ("fig16_main", "Fig.16   MAIN: SLO attainment + energy"),
    ("fig17_ablation", "Fig.17/28 EcoFreq-only vs full + phase split"),
    ("fig18_traces", "Fig.18/31 frequency/batch traces"),
    ("fig19_slo_profiles", "Fig.19   SLO profile sweep"),
    ("fig20_control_interval", "Fig.20   control-interval ablation"),
    ("fig21_ecopred_mae", "Fig.21   EcoPred offline vs online MAE"),
    ("fig22_gh200", "Fig.22   GH200 generalization"),
    ("fig25_throughput", "Fig.25   throughput comparison"),
    ("fig26_27_static_powercap", "Fig.26/27 static-intermediate + powercap"),
    ("fig29_30_levels_delta", "Fig.29/30 freq levels + delta sweep"),
    ("tab2_pd_ratio", "Tab.II   synthetic P/D-ratio workload"),
    ("fig34_cdfs", "Fig.34   TTFT/ITL CDFs at low/high RPS"),
    ("fig_hetero_autoscale", "EcoScale hetero fleet + autoscale vs static"),
    ("fig_prefix_cache", "Chunked prefill + radix prefix cache (multi-turn)"),
    ("fig_slo_tiers", "Multi-tenant SLO tiers vs single-tier baseline"),
    ("fig_specdec", "Speculative draft-verify decode vs single-token"),
    ("fig_traces_replay", "Scenario matrix replay + open-loop QPS knees"),
    ("roofline", "§Roofline table from dry-run records"),
    ("perf_iterations", "§Perf    hillclimb log from perf records"),
]

QUICK = {"fig1_5_ucurve", "fig4_itl_sensitivity", "fig6_staircase",
         "fig13_state_space", "fig20_control_interval", "roofline"}

# CI smoke: fast analytic sanity + the EcoScale serving scenario + the
# prefix-cache + SLO-tier scenarios (all read BENCH_SMOKE=1 and shrink
# their traces)
SMOKE = {"fig1_5_ucurve", "fig6_staircase", "fig_hetero_autoscale",
         "fig_prefix_cache", "fig_slo_tiers", "fig_specdec",
         "fig_traces_replay"}


def _write_bench_serving(module_status: dict) -> str:
    """Machine-readable perf snapshot for cross-PR tracking (CI
    artifact): the Sim event loop timed on a fixed reference scenario —
    legacy and paged KV accounting — plus each smoke module's status."""
    from benchmarks.perf_iterations import (
        event_loop_benchmark,
        real_mesh_benchmark,
    )

    bank = {}  # one EcoPred fit shared by both variants
    event_loop = {
        "dense": event_loop_benchmark(paged=False, predictor_bank=bank),
        "paged": event_loop_benchmark(paged=True, predictor_bank=bank),
        "spec_decode": event_loop_benchmark(
            paged=True, spec=True, predictor_bank=bank
        ),
        # real JAX execution on a tp=1 mesh slice: gates the mesh-keyed
        # jit cache (warm run must replay, recompiles == 0) and the
        # virtual-clock golden pin through the sharded code path
        "real_mesh_tp1": real_mesh_benchmark(tp=1),
    }
    payload = {
        "schema": 2,
        "generated_by": "benchmarks.run --smoke",
        "event_loop": event_loop,
        # Phase split of the dense loop (separate instrumented run; its
        # iters_per_s is NOT the headline number — wrappers cost time).
        "event_loop_breakdown": event_loop_benchmark(
            paged=False, predictor_bank=bank, breakdown=True
        ).get("breakdown"),
        # standing depth-K data for the K>1 default question (ROADMAP):
        # the same real tp=1 scenario with the async-dispatch ring at
        # each depth; k1 is the headline real_mesh_tp1 row itself
        "pipeline_depth_sweep": {
            "k1": event_loop["real_mesh_tp1"],
            "k2": real_mesh_benchmark(tp=1, pipeline_depth=2),
            "k4": real_mesh_benchmark(tp=1, pipeline_depth=4),
        },
        "modules": module_status,
    }
    replay_path = os.path.join(os.path.dirname(__file__), "results",
                               "fig_traces_replay.json")
    if os.path.exists(replay_path):  # scenario matrix + open-loop QPS
        # knees (written by fig_traces_replay earlier in this smoke run)
        with open(replay_path) as f:
            payload["trace_replay"] = json.load(f)
    base_path = os.path.join(os.path.dirname(__file__),
                             "BENCH_baseline.json")
    if os.path.exists(base_path):  # embed the committed pre-PR rows so
        # the artifact is self-describing (gate math lives in
        # tools/bench_gate.py, which re-reads the baseline itself)
        with open(base_path) as f:
            base = json.load(f)
        pre = base.get("pre_pr", {})
        payload["pre_pr"] = pre
        payload["speedup_vs_pre_pr"] = {
            k: round(event_loop[k]["iters_per_s"]
                     / pre[k]["iters_per_s"], 2)
            for k in event_loop
            if pre.get(k, {}).get("iters_per_s")
            and event_loop[k].get("iters_per_s")
        }
    out_dir = os.path.join(os.path.dirname(__file__), "results")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def main() -> int:
    args = [a for a in sys.argv[1:] if not a.startswith("-")]
    quick = "--quick" in sys.argv
    smoke = "--smoke" in sys.argv
    if smoke:
        os.environ["BENCH_SMOKE"] = "1"
    failures = 0
    module_status = {}
    for name, desc in MODULES:
        if args and not any(a in name for a in args):
            continue
        if quick and name not in QUICK:
            continue
        if smoke and name not in SMOKE:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            rows = mod.run()
            n = len(rows) if rows is not None else 0
            module_status[name] = {
                "status": "ok", "rows": n,
                "wall_s": round(time.time() - t0, 1),
            }
            print(f"[ok]   {desc:45s} {n:4d} rows  {time.time()-t0:6.1f}s",
                  flush=True)
        except (Exception, SystemExit) as e:
            # SystemExit too: a script-style `sys.exit(0)` inside a
            # figure module must fail *this* module, not silently end
            # the whole sweep with a green exit code.
            failures += 1
            module_status[name] = {
                "status": "fail", "error": f"{type(e).__name__}: {e}",
            }
            print(f"[FAIL] {desc:45s} {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    if smoke and not args:  # full smoke only: a filtered run would
        # masquerade as a complete perf snapshot
        try:
            path = _write_bench_serving(module_status)
            print(f"[ok]   BENCH_serving.json -> {path}", flush=True)
        except Exception as e:
            failures += 1
            print(f"[FAIL] BENCH_serving.json {type(e).__name__}: {e}",
                  flush=True)
            traceback.print_exc()
    print(f"\nbenchmarks done ({failures} failures); results in "
          "benchmarks/results/")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
