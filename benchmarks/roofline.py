"""§Roofline: three-term roofline per (arch × shape) single-pod cell.

Reads the dry-run record (``dryrun_results.jsonl``) and derives, per cell:

    compute term    = HLO_FLOPs/device  / (197 TFLOP/s bf16)
    memory term     = HBM bytes/device  / (819 GB/s)
    collective term = wire bytes/device / (50 GB/s/link)

* FLOPs: exact loop-free lowered-HLO totals (dry-run ``flops_per_device``).
* HBM bytes: analytic traffic model (weights + cache + activation streams
  under the cell's remat/microbatch policy) — the pre-fusion HLO byte
  count is kept as an upper bound (``hlo_bytes_global``).
* Collectives: the sharding-policy traffic model (``comm_model_bytes``),
  cross-checked against the HLO op mix.

Also reports MODEL_FLOPS (6·N·D train / 2·N·D inference, active params
for MoE) and MODEL_FLOPS/HLO_FLOPs — the useful-compute fraction that
exposes remat/padding waste — plus the dominant term and what would move
it down.
"""
from __future__ import annotations

import json
import os
from typing import Optional

from repro.configs.registry import REGISTRY
from repro.configs.shapes import SHAPES
from repro.core.power import TPU_V5E

from benchmarks.common import write_csv

BF16 = 2
F32 = 4


def _hbm_traffic_per_device(rec: dict) -> float:
    """First-order per-device HBM bytes for one step."""
    from repro.launch.dryrun import apply_variant

    cfg = apply_variant(REGISTRY[rec["arch"]], rec.get("variant") or {})
    shape = SHAPES[rec["shape"]]
    n_dev = rec["n_devices"]
    mem = rec["mem_model_gb"]
    p_local = mem["params"] * 1e9
    mb = rec.get("microbatches", 1)
    if shape.kind == "train":
        act_stream = mem["saved_residuals"] * 1e9
        # fwd + bwd + remat-refwd weight reads, grad write/read, opt update
        return (
            3 * p_local * mb  # weights touched per microbatch pass
            + 2 * mem["grads_fp32"] * 1e9
            + 3 * mem["opt_mv"] * 1e9 / 2
            + 4 * act_stream
        )
    # serving reads the full model-axis weight shard each step (FSDP-held
    # fractions are gathered into HBM first, then read — same traffic)
    w_elem = 1.02 if cfg.weight_dtype == "int8" else 2
    w_read = cfg.param_count() * w_elem / 16  # model axis = 16
    if shape.kind == "prefill":
        return w_read + mem.get("cache_out", 0) * 1e9 + \
            mem.get("activations", 0) * 1e9 * 4
    return (
        w_read
        + mem.get("cache", 0) * 1e9
        + mem.get("activations", 0) * 1e9
    )


def model_flops(arch: str, shape_name: str) -> float:
    cfg = REGISTRY[arch]
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token/request


def terms_for_record(rec: dict, chip=TPU_V5E) -> dict:
    """Three roofline terms (seconds) for one dry-run record."""
    return {
        "compute": rec["flops_per_device"] / chip.peak_flops,
        "memory": _hbm_traffic_per_device(rec) / chip.hbm_bw,
        "collective": rec["comm_model_bytes"]["total"] / chip.ici_bw,
    }


def _advice(dom: str, rec: dict) -> str:
    if dom == "collective":
        return ("sequence-parallel TP (reduce-scatter + all-gather instead "
                "of all-reduce) / overlap collectives with compute")
    if dom == "memory":
        if SHAPES[rec["shape"]].kind == "decode":
            return ("larger decode batch per chip (raise arithmetic "
                    "intensity) / quantize KV cache to int8")
        return "fuse activation streams; fewer remat passes"
    return ("reduce padding waste (MXU tile alignment) and remat recompute; "
            "already compute-bound — near the ideal regime")


def run(out_dir=None, results_path: Optional[str] = None):
    results_path = results_path or os.path.join(
        os.path.dirname(__file__), "..", "dryrun_results.jsonl"
    )
    rows = []
    if not os.path.exists(results_path):
        print(f"no dry-run results at {results_path}; run "
              "`python -m repro.launch.dryrun --all --out "
              "dryrun_results.jsonl` first")
        return rows
    chip = TPU_V5E
    with open(results_path) as f:
        recs = [json.loads(line) for line in f if line.strip()]
    for rec in recs:
        if rec.get("status") != "ok" or rec.get("mesh") != "16x16":
            continue
        terms = terms_for_record(rec, chip)
        t_comp, t_mem, t_coll = (
            terms["compute"], terms["memory"], terms["collective"],
        )
        dom = max(terms, key=terms.get)
        mf = model_flops(rec["arch"], rec["shape"])
        hlo_f = rec["flops_global"]
        total = sum(terms.values())
        n_dev = rec["n_devices"]
        # roofline fraction: model-useful compute time / estimated step
        # time (serial-term estimate). 1.0 == the chip does nothing but
        # useful model math. This is the §Perf score.
        t_useful = mf / n_dev / chip.peak_flops
        rows.append({
            "arch": rec["arch"],
            "shape": rec["shape"],
            "compute_s": f"{t_comp:.4e}",
            "memory_s": f"{t_mem:.4e}",
            "collective_s": f"{t_coll:.4e}",
            "dominant": dom,
            "roofline_frac": round(t_useful / total, 4),
            "dominant_share": round(terms[dom] / total, 3),
            "model_flops": f"{mf:.3e}",
            "hlo_flops_global": f"{hlo_f:.3e}",
            "useful_frac": round(mf / hlo_f, 3) if hlo_f else 0.0,
            "peak_mem_gb": round(rec["mem_model_gb"]["total"], 2),
            "advice": _advice(dom, rec),
        })
    write_csv("roofline", rows, out_dir)
    return rows


if __name__ == "__main__":
    for r in run():
        print({k: v for k, v in r.items() if k != "advice"})
