"""Fig. 10: latency predictability — prefill is near-linear in batched
tokens; decode is a tile-structured surface over (N_req, N_kv).
Emits the profiling scatter EcoPred trains on (uniform sampling + noise).
"""
from __future__ import annotations

import numpy as np

from repro.configs.registry import REGISTRY
from repro.core.hwmodel import HardwareModel
from repro.core.power import A100

from benchmarks.common import write_csv


def run(out_dir=None):
    hw = HardwareModel(REGISTRY["llama-3.1-8b"], A100)
    rng = np.random.default_rng(0)
    rows = []
    for _ in range(400):
        n_tok = int(rng.integers(1, 16384))
        f = float(rng.choice(A100.freq_levels_2))
        t = hw.prefill_time(n_tok, f) * float(np.exp(rng.normal(0, 0.03)))
        rows.append({
            "phase": "prefill", "freq_mhz": f, "n_tok": n_tok,
            "n_req": "", "n_kv": "", "time_ms": round(t * 1e3, 4),
        })
    for _ in range(800):
        n_req = int(rng.integers(1, 512))
        n_kv = int(n_req * rng.integers(100, 4000))
        f = float(rng.choice(A100.freq_levels_2))
        t = hw.decode_time(n_req, n_kv, f) * float(np.exp(rng.normal(0, 0.03)))
        rows.append({
            "phase": "decode", "freq_mhz": f, "n_tok": "",
            "n_req": n_req, "n_kv": n_kv, "time_ms": round(t * 1e3, 4),
        })
    write_csv("fig10_predictability", rows, out_dir)
    return rows[:5]


if __name__ == "__main__":
    run()
    print("fig10 written")
