"""Appx. N (Table II): synthetic workload whose prefill/decode demand
ratio oscillates on a 5-minute period. VoltanaLLM keeps near-max-freq SLO
attainment with large energy savings; the P and D instances' frequencies
move in opposition as the demand mix shifts.
"""
from __future__ import annotations

from repro.serving.workload import synthetic_pd_ratio

from benchmarks.common import serve_once, write_csv


def run(out_dir=None, duration=600.0, rps=12.0):
    rows = []
    for policy, static in (
        ("voltana", None), ("static", 1005.0), ("static", 1410.0),
    ):
        reqs = synthetic_pd_ratio(rps, duration, period_s=150.0, seed=11)
        row, m, cluster = serve_once(
            "llama-3.1-8b", policy, rps, static_freq=static,
            requests=reqs, record_traces=(policy == "voltana"),
            return_metrics=True,
        )
        rows.append(row)
        if policy == "voltana":
            trace_rows = []
            for e in m.instances:
                hi_frac = (
                    sum(1 for (_, f, _) in e.freq_trace if f > 1200)
                    / max(1, len(e.freq_trace))
                )
                trace_rows.append({
                    "model": "llama-3.1-8b", "policy": "voltana-trace",
                    "dataset": e.name, "rps": rps,
                    "hi_freq_frac": round(hi_frac, 3),
                })
            rows += trace_rows
    write_csv("tab2_pd_ratio", rows, out_dir)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
