"""Fig. 6 (+ Appx. A): tile-quantization "staircase" in decode ITL and
energy-per-output-token as batch size crosses GEMM M-tile boundaries.

On the A100 target the boundary period is 256 (paper); on the TPU v5e
target it is the 128-wide MXU tile (DESIGN.md §2 hardware adaptation).
The prefill staircase exists at small token counts and washes out above
~2k batched tokens (Appx. A).
"""
from __future__ import annotations

from repro.configs.registry import REGISTRY
from repro.core.hwmodel import HardwareModel
from repro.core.power import A100, TPU_V5E

from benchmarks.common import write_csv


def run(out_dir=None):
    rows = []
    for chip in (A100, TPU_V5E):
        hw = HardwareModel(REGISTRY["llama-3.1-8b"], chip)
        t = chip.mxu_tile
        for bs in sorted({
            *range(max(1, t - 8), t + 9),
            *range(2 * t - 8, 2 * t + 9),
            *range(16, 3 * t, 16),
        }):
            c = hw.decode_iter(bs, bs * 800, chip.f_max)
            rows.append({
                "chip": chip.name, "phase": "decode", "batch": bs,
                "itl_ms": round(c.time_s * 1e3, 4),
                "epot_mj": round(c.energy_j / bs * 1e3, 4),
            })
        # prefill staircase (Appx. A): visible small, washed out large
        for ntok in (*range(t - 4, t + 5), 512, 1024, 2048, 4096, 8192):
            c = hw.prefill_iter(ntok, ntok, chip.f_max)
            rows.append({
                "chip": chip.name, "phase": "prefill", "batch": ntok,
                "itl_ms": round(c.time_s * 1e3, 4),
                "epot_mj": round(c.energy_j / ntok * 1e3, 4),
            })
    write_csv("fig6_staircase", rows, out_dir)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
