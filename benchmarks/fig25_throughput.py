"""Appx. E (Fig. 25): throughput — VoltanaLLM approaches SGLang-1410's
throughput at high RPS (where it boosts) and trades a little at low RPS.
"""
from __future__ import annotations

from benchmarks.common import RPS_GRID, serve_once, write_csv


def run(out_dir=None, duration=90.0):
    rows = []
    for rps in RPS_GRID["llama-3.1-8b"]:
        for policy, static in (
            ("voltana", None), ("static", 1005.0), ("static", 1410.0),
        ):
            rows.append(serve_once(
                "llama-3.1-8b", policy, rps, duration=duration,
                static_freq=static,
            ))
    write_csv("fig25_throughput", rows, out_dir)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
