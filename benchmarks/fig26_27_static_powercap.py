"""Appx. G/H (Fig. 26/27): intermediate static frequencies (1095/1200/
1305 MHz) and a 350 W power cap, vs VoltanaLLM. Static intermediates
waste energy at low RPS and miss SLOs at high RPS; the cap blocks
boosting under pressure and doesn't down-clock at low load.
"""
from __future__ import annotations

from benchmarks.common import serve_once, write_csv


def run(out_dir=None, duration=90.0):
    rows = []
    for rps in (4, 10, 20, 30):
        rows.append(serve_once("llama-3.1-8b", "voltana", rps,
                               duration=duration))
        for f in (1095.0, 1200.0, 1305.0):
            rows.append(serve_once(
                "llama-3.1-8b", "static", rps, duration=duration,
                static_freq=f,
            ))
        rows.append(serve_once(
            "llama-3.1-8b", "powercap", rps, duration=duration,
            power_cap_w=350.0,
        ))
    write_csv("fig26_27_static_powercap", rows, out_dir)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
