"""Fig. 21 (+ Fig. 11): EcoPred accuracy — offline-only vs online-adapted
MAE under a shifted online distribution (the offline profile is uniform;
the serving workload concentrates elsewhere — Fig. 11's shift).
"""
from __future__ import annotations

import numpy as np

from repro.configs.registry import REGISTRY
from repro.core.ecopred import EcoPred, ProfileRanges
from repro.core.hwmodel import HardwareModel
from repro.core.power import A100

from benchmarks.common import PAPER_SETUPS, write_csv


def run(out_dir=None):
    rows = []
    rng = np.random.default_rng(3)
    for model_name in ("ministral-3b", "llama-3.1-8b", "qwen3-32b"):
        tp = PAPER_SETUPS[model_name][2]
        hw = HardwareModel(REGISTRY[model_name], A100, tp)
        pred = EcoPred(A100.freq_levels_2, seed=1)
        pred.offline_profile(hw, ProfileRanges(max_kv_tokens=600_000))

        # online distribution: concentrated (ShareGPT-ish state occupancy)
        def online_batch(n):
            n_req = rng.integers(32, 200, n)
            n_kv = (n_req * rng.normal(450, 60, n)).astype(int).clip(1_000)
            f = rng.choice(A100.freq_levels_2, n)
            y = np.array([
                hw.decode_time(int(q), int(k), float(ff))
                for q, k, ff in zip(n_req, n_kv, f)
            ]) * np.exp(rng.normal(0.0, 0.03, n))
            # mild systematic shift vs offline (kernel autotuning drift)
            y = y * 1.06
            return np.stack([f, n_req, n_kv], 1), y

        Xe, ye = online_batch(500)
        mae_off = float(np.abs(pred.predict_decode(
            Xe[:, 0], Xe[:, 1], Xe[:, 2]) - ye).mean())
        for _ in range(4):  # online adaptation rounds
            Xa, ya = online_batch(600)
            pred.decode_model.continue_fit(Xa, ya, n_more=25)
        mae_on = float(np.abs(pred.predict_decode(
            Xe[:, 0], Xe[:, 1], Xe[:, 2]) - ye).mean())
        rows.append({
            "model": model_name, "phase": "decode (ITL)",
            "mae_offline_ms": round(mae_off * 1e3, 3),
            "mae_online_ms": round(mae_on * 1e3, 3),
            "improvement_pct": round(100 * (1 - mae_on / mae_off), 1),
        })

        # prefill
        def online_prefill(n):
            n_tok = rng.integers(64, 4096, n)
            f = rng.choice(A100.freq_levels_2, n)
            y = np.array([
                hw.prefill_time(int(t), float(ff))
                for t, ff in zip(n_tok, f)
            ]) * np.exp(rng.normal(0.0, 0.03, n)) * 1.05
            return np.stack([f, n_tok], 1), y

        Xe, ye = online_prefill(400)
        mae_off = float(np.abs(pred.predict_prefill(
            Xe[:, 0], Xe[:, 1]) - ye).mean())
        for _ in range(4):
            Xa, ya = online_prefill(500)
            pred.prefill_model.continue_fit(
                pred._pfeat(Xa[:, 0], Xa[:, 1]), ya
            )
        mae_on = float(np.abs(pred.predict_prefill(
            Xe[:, 0], Xe[:, 1]) - ye).mean())
        rows.append({
            "model": model_name, "phase": "prefill (TTFT)",
            "mae_offline_ms": round(mae_off * 1e3, 3),
            "mae_online_ms": round(mae_on * 1e3, 3),
            "improvement_pct": round(100 * (1 - mae_on / mae_off), 1),
        })
    write_csv("fig21_ecopred_mae", rows, out_dir)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
