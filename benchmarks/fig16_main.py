"""Fig. 16 (main result): TTFT/ITL SLO attainment + E2E energy across
models × datasets × request rates, VoltanaLLM vs the SGLang-1005 /
SGLang-1410 static baselines (2P2D, F = {1005, 1410} MHz, Δ = 500).

Expected shape (paper): VoltanaLLM ≈ SGLang-1410 attainment with up to
~36% less energy; SGLang-1005 saves energy but collapses SLO attainment
at high RPS.
"""
from __future__ import annotations

from benchmarks.common import RPS_GRID, serve_once, write_csv

MODELS = ("ministral-3b", "llama-3.1-8b", "qwen3-32b")
DATASETS = ("sharegpt", "lmsys")


def run(out_dir=None, models=MODELS, datasets=DATASETS, duration=90.0):
    rows = []
    for model in models:
        for ds in datasets:
            for rps in RPS_GRID[model]:
                rows.append(serve_once(
                    model, "voltana", rps, dataset=ds, duration=duration))
                rows.append(serve_once(
                    model, "static", rps, dataset=ds, duration=duration,
                    static_freq=1005.0))
                rows.append(serve_once(
                    model, "static", rps, dataset=ds, duration=duration,
                    static_freq=1410.0))
                v, lo, hi = rows[-3], rows[-2], rows[-1]
                v["energy_vs_1410_pct"] = round(
                    100 * (1 - v["energy_j"] / hi["energy_j"]), 1
                )
    write_csv("fig16_main", rows, out_dir)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
