"""EcoScale: heterogeneous fleet + autoscaling + phase-aware placement.

The fleet-scale scenario beyond the paper's fixed 2P2D setup: a diurnal
``azure_like`` trace (conversation flat, code peaking mid-window) served
by a *mixed* A100 + GH200 fleet under EcoScale — per-chip frequency
ladders, energy-aware what-if placement, and the drain/park/re-admit
autoscaler — against static homogeneous max-frequency baselines of the
same slot count (the provision-for-peak deployments EcoScale replaces).

Rows: one per policy, plus a ``delta_vs_*`` summary comparing EcoScale
with each baseline on energy and SLO attainment.

    PYTHONPATH=src python -m benchmarks.run fig_hetero_autoscale
    BENCH_SMOKE=1 ... (or --smoke)  -> shortened trace for CI
"""
from __future__ import annotations

import os

from benchmarks.common import write_csv
from repro.configs.registry import REGISTRY
from repro.core.power import A100, GH200
from repro.serving import (
    AutoScaleConfig,
    ClusterConfig,
    InstanceSpec,
    PDCluster,
    azure_like,
    homogeneous_fleet,
)

MODEL_NAME = "llama-3.1-8b"
# The azure-like trace's prompts run 5-7x ShareGPT length (code class mean
# 2048, tail >10k); the paper's SLO tiers scale with work, so this
# scenario uses the long-prompt TTFT tier while keeping the 8B ITL SLO.
# (A >10k-token prompt is >0.6 s of pure prefill on every chip here.)
SLO_TTFT_S, SLO_ITL_S = 1.0, 0.06

# GH200 phase-split ladders (paper Appx. M): prefill sweet 1095, decode 1395
GH200_P = (1095.0, 1980.0)
GH200_D = (1395.0, 1980.0)


def _mixed_fleet():
    """Phase-aware provisioning (DualScale-style): prefill on GH200 —
    compute-hungry phase, most efficient at its 1095 MHz voltage knee —
    and decode mostly on A100s, which win J/token at low occupancy, with
    one GH200 decode for peak absorption.  EcoScale parks whatever the
    trough doesn't need."""
    prefill = [
        InstanceSpec(GH200, freq_options=GH200_P),
        InstanceSpec(GH200, freq_options=GH200_P),
    ]
    decode = [
        InstanceSpec(A100),
        InstanceSpec(A100),
        InstanceSpec(GH200, freq_options=GH200_D),
    ]
    return prefill, decode


def _run_one(label, reqs, bank, **cfg_kw):
    cfg_kw.setdefault("chip", A100)
    cfg = ClusterConfig(
        model=REGISTRY[MODEL_NAME],
        slo_ttft_s=SLO_TTFT_S,
        slo_itl_s=SLO_ITL_S,
        online_adapt=False,
        predictor_bank=bank,
        seed=0,
        **cfg_kw,
    )
    cluster = PDCluster(cfg)
    m = cluster.run([_reset(r) for r in reqs])
    row = {"policy": label, "model": MODEL_NAME, **m.summary()}
    if cluster.autoscaler is not None:
        row["scale_events"] = len(cluster.autoscaler.events)
    return row


def _reset(r):
    return r  # PDCluster.run() resets request lifecycle state itself


def run(out_dir=None):
    smoke = os.environ.get("BENCH_SMOKE") == "1"
    duration = 240.0 if smoke else 600.0
    base_rps = 3.0 if smoke else 4.0
    # one full diurnal cycle: trough -> peak -> trough (day == window)
    reqs = azure_like(base_rps, duration, seed=11, day_s=duration,
                      t0_frac=0.0)

    bank = {}
    pre, dec = _mixed_fleet()
    rows = [
        _run_one(
            "ecoscale", reqs, bank,
            policy="voltana",
            prefill_fleet=pre,
            decode_fleet=dec,
            autoscale=AutoScaleConfig(interval_s=2.0, cooldown_s=6.0),
        ),
        _run_one(
            "static-gh200-max", reqs, bank,
            policy="static", static_freq=GH200.f_max, chip=GH200,
            prefill_fleet=homogeneous_fleet(GH200, 2, freq_options=GH200_P),
            decode_fleet=homogeneous_fleet(GH200, 3, freq_options=GH200_D),
        ),
        _run_one(
            "static-a100-max", reqs, bank,
            policy="static", static_freq=A100.f_max,
            prefill_fleet=homogeneous_fleet(A100, 2),
            decode_fleet=homogeneous_fleet(A100, 3),
        ),
    ]

    eco = rows[0]
    for base in rows[1:]:
        rows.append({
            "policy": f"delta_vs_{base['policy']}",
            "model": MODEL_NAME,
            "energy_saving_frac": round(
                1.0 - eco["energy_j"] / base["energy_j"], 4
            ),
            "ttft_attain_delta": round(
                eco["ttft_attain"] - base["ttft_attain"], 4
            ),
            "itl_attain_delta": round(
                eco["itl_attain"] - base["itl_attain"], 4
            ),
        })
        print(
            f"  ecoscale vs {base['policy']:18s}: "
            f"energy {eco['energy_j']:9.0f} J vs {base['energy_j']:9.0f} J "
            f"({100 * (1 - eco['energy_j'] / base['energy_j']):+.1f}%)  "
            f"ttft {eco['ttft_attain']:.3f} vs {base['ttft_attain']:.3f}  "
            f"itl {eco['itl_attain']:.3f} vs {base['itl_attain']:.3f}"
        )

    write_csv("fig_hetero_autoscale", rows, out_dir)
    return rows
