"""Speculative draft–verify decoding vs single-token decode.

One iteration of the target model that *verifies* a k-token draft emits
several accepted tokens for one pass over the weight/KV streams, so the
energy per emitted token — the quantity VoltanaLLM's U-curve sweet spots
actually optimize — drops wherever drafts verify well.  This benchmark
serves one acceptance-heterogeneous trace (``templated`` code-like
traffic that drafts well + ``chat`` traffic that doesn't —
``spec_heterogeneity_workload``) on the same 2P2D A100 fleet under:

* ``baseline``    — ``spec_decode=False``: the legacy single-token
  decode path (bit-exact with pre-speculation main);
* ``specdec-k4``  — draft–verify speculation (``spec_k=4``): variable-
  yield decode iterations, EcoFreq pacing against ITL per *emitted*
  token via the per-instance acceptance EWMA, acceptance-aware EcoRoute
  pricing J per emitted token;
* ``specdec-k4[uniform-route]`` — ablation (full run only): speculation
  on but acceptance hidden from the router (round-robin placement), so
  the delta to ``specdec-k4`` isolates the acceptance state-space
  dimension.

Acceptance (pinned by tests/test_golden_smoke.py): lower energy per
emitted token than ``baseline`` at equal-or-better TTFT/ITL attainment.

    PYTHONPATH=src python -m benchmarks.run fig_specdec
    BENCH_SMOKE=1 ... (or --smoke)  -> shortened trace for CI
"""
from __future__ import annotations

import os

from benchmarks.common import write_csv
from repro.configs.registry import REGISTRY
from repro.core.power import A100
from repro.serving import (
    ClusterConfig,
    PDCluster,
    spec_heterogeneity_workload,
)

MODEL_NAME = "llama-3.1-8b"
SLO_TTFT_S, SLO_ITL_S = 0.6, 0.06


def _run_one(label, reqs, bank, **cfg_kw):
    cfg = ClusterConfig(
        model=REGISTRY[MODEL_NAME],
        chip=A100,
        n_prefill=2,
        n_decode=2,
        slo_ttft_s=SLO_TTFT_S,
        slo_itl_s=SLO_ITL_S,
        policy="voltana",
        online_adapt=False,
        predictor_bank=bank,
        seed=0,
        paged=True,
        **cfg_kw,
    )
    m = PDCluster(cfg).run(reqs)
    return {"policy": label, "model": MODEL_NAME, **m.summary()}, m


def run(out_dir=None):
    smoke = os.environ.get("BENCH_SMOKE") == "1"
    base_rps = 10.0 if smoke else 12.0
    duration = 90.0 if smoke else 240.0
    reqs = spec_heterogeneity_workload(base_rps, duration, seed=11)

    bank = {}
    rows = []
    base_row, base = _run_one("baseline", reqs, bank, spec_decode=False)
    rows.append(base_row)
    # snapshot base scalars NOW: RunMetrics aliases the Request objects,
    # which the next arm resets and re-runs
    b_epot, b_energy = base.energy_per_token_j(), base.energy_j()
    b_ttft, b_itl = base.ttft_attainment(), base.itl_attainment()

    arms = [("specdec-k4", dict(spec_decode=True, spec_k=4))]
    if not smoke:
        arms.append((
            "specdec-k4[uniform-route]",
            dict(spec_decode=True, spec_k=4, policy="ecofreq-only"),
        ))
    for label, kw in arms:
        row, m = _run_one(label, reqs, bank, **kw)
        rows.append(row)
        assert m.finished_frac() == 1.0, (
            f"{label}: requests lost (finished_frac={m.finished_frac()})"
        )
        rows.append({
            "policy": f"delta_vs_baseline[{label}]",
            "model": MODEL_NAME,
            "epot_saving_frac": round(
                1.0 - m.energy_per_token_j() / b_epot, 4
            ),
            "energy_saving_frac": round(1.0 - m.energy_j() / b_energy, 4),
            "tok_per_j": round(m.tokens_per_joule(), 3),
            "ttft_attain_delta": round(m.ttft_attainment() - b_ttft, 4),
            "itl_attain_delta": round(m.itl_attainment() - b_itl, 4),
            "accept_rate": round(m.acceptance_rate() or 0.0, 4),
            "spec_yield": round(m.spec_yield() or 0.0, 4),
        })
        print(
            f"  {label:26s} vs baseline: "
            f"energy/tok {m.energy_per_token_j()*1e3:7.2f} mJ vs "
            f"{b_epot*1e3:7.2f} mJ "
            f"({100 * (1 - m.energy_per_token_j() / b_epot):+.1f}%)  "
            f"ttft {m.ttft_attainment():.3f} vs {b_ttft:.3f}  "
            f"itl {m.itl_attainment():.3f} vs {b_itl:.3f}  "
            f"yield {m.spec_yield() or 0.0:.2f} "
            f"accept {m.acceptance_rate() or 0.0:.2f}"
        )

    write_csv("fig_specdec", rows, out_dir)
    return rows
