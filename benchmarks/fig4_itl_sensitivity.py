"""Fig. 4: decode ITL reduction from frequency scaling (1005→1410 MHz)
grows with batch size — decode transitions memory-bound → compute-bound.
"""
from __future__ import annotations

from repro.configs.registry import REGISTRY
from repro.core.hwmodel import HardwareModel
from repro.core.power import A100

from benchmarks.common import write_csv


def run(out_dir=None):
    hw = HardwareModel(REGISTRY["llama-3.1-8b"], A100)
    rows = []
    for bs in (1, 8, 32, 64, 128, 256, 384, 512):
        t_lo = hw.decode_time(bs, bs * 1000, 1005.0)
        t_hi = hw.decode_time(bs, bs * 1000, 1410.0)
        rows.append({
            "batch_size": bs,
            "itl_lo_ms": round(t_lo * 1e3, 3),
            "itl_hi_ms": round(t_hi * 1e3, 3),
            "itl_decrease_pct": round(100 * (1 - t_hi / t_lo), 2),
            "theta": round(hw.decode_iter(bs, bs * 1000, 1410.0).theta, 3),
        })
    write_csv("fig4_itl_sensitivity", rows, out_dir)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
