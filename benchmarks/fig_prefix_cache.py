"""Chunked prefill + radix prefix cache on a multi-turn azure-like trace.

Multi-turn/agentic traffic re-sends the conversation so far every turn;
the shared-prefix compute is the dominant redundant energy cost that
VoltanaLLM's frequency control alone cannot recover.  This benchmark
serves one multi-turn trace (shared system prompts, growing histories)
under three configurations of the same 2P2D A100 fleet:

* ``no-cache-whole-prompt`` — the pre-chunking baseline: whole-prompt
  FCFS batching (oversized prompts bypass the token budget), no reuse;
* ``chunked``               — chunk-iteration scheduling under a strict
  token budget, still recomputing every prompt from scratch;
* ``chunked+radix-cache``   — chunked prefill over per-instance radix
  prefix caches with cache-affinity prefill routing.

Rows: one per policy plus ``delta_vs_*`` summaries (energy/token saving,
TTFT/ITL attainment deltas, prefix hit rate).

    PYTHONPATH=src python -m benchmarks.run fig_prefix_cache
    BENCH_SMOKE=1 ... (or --smoke)  -> shortened trace for CI
"""
from __future__ import annotations

import os

from benchmarks.common import write_csv
from repro.configs.registry import REGISTRY
from repro.core.power import A100
from repro.serving import ClusterConfig, PDCluster, multiturn_workload

MODEL_NAME = "llama-3.1-8b"
# long-prompt tier (multi-turn histories run to thousands of tokens)
SLO_TTFT_S, SLO_ITL_S = 1.0, 0.06


def _run_one(label, reqs, bank, **cfg_kw):
    cfg = ClusterConfig(
        model=REGISTRY[MODEL_NAME],
        chip=A100,
        n_prefill=2,
        n_decode=2,
        slo_ttft_s=SLO_TTFT_S,
        slo_itl_s=SLO_ITL_S,
        online_adapt=False,
        predictor_bank=bank,
        seed=0,
        **cfg_kw,
    )
    m = PDCluster(cfg).run(reqs)
    return {"policy": label, "model": MODEL_NAME, **m.summary()}, m


def run(out_dir=None):
    smoke = os.environ.get("BENCH_SMOKE") == "1"
    n_conv = 140 if smoke else 420
    duration = 120.0 if smoke else 360.0
    reqs = multiturn_workload(
        n_conv, duration, seed=13, think_mean_s=4.0, turns_mean=6.0
    )

    bank = {}
    rows = []
    base_row, base = _run_one(
        "no-cache-whole-prompt", reqs, bank,
        policy="voltana", chunked_prefill=False, prefix_cache=False,
    )
    rows.append(base_row)
    # snapshot base scalars NOW: RunMetrics aliases the Request objects,
    # which the next arm resets and re-runs
    b_epot, b_energy = base.epot_j(), base.energy_j()
    b_ttft, b_itl = base.ttft_attainment(), base.itl_attainment()
    for label, kw in [
        ("chunked", dict(chunked_prefill=True, prefix_cache=False)),
        ("chunked+radix-cache", dict(chunked_prefill=True,
                                     prefix_cache=True)),
    ]:
        row, m = _run_one(label, reqs, bank, policy="voltana", **kw)
        rows.append(row)
        rows.append({
            "policy": f"delta_vs_base[{label}]",
            "model": MODEL_NAME,
            "epot_saving_frac": round(
                1.0 - m.energy_per_token_j() / b_epot, 4
            ),
            "energy_saving_frac": round(1.0 - m.energy_j() / b_energy, 4),
            "tok_per_j": round(m.tokens_per_joule(), 3),
            "ttft_attain_delta": round(m.ttft_attainment() - b_ttft, 4),
            "itl_attain_delta": round(m.itl_attainment() - b_itl, 4),
            "prefix_hit_rate": row.get("prefix_hit_rate", 0.0),
        })
        print(
            f"  {label:22s} vs whole-prompt: "
            f"energy/tok {m.epot_j()*1e3:8.2f} mJ vs "
            f"{b_epot*1e3:8.2f} mJ "
            f"({100 * (1 - m.epot_j() / b_epot):+.1f}%)  "
            f"ttft {m.ttft_attainment():.3f} vs {b_ttft:.3f}  "
            f"itl {m.itl_attainment():.3f} vs {b_itl:.3f}  "
            f"hit {row.get('prefix_hit_rate', 0.0):.2f}"
        )

    write_csv("fig_prefix_cache", rows, out_dir)
    return rows
