"""Fig. 2 + Fig. 3: multi-timescale workload dynamics.

Fig. 2 — coarse timescale: hourly prefill/decode token demand of the
Azure-like two-class trace (conversation ~flat, code diurnal with short
decodes ⇒ decode demand varies much less than prefill).

Fig. 3 — fine timescale: iteration-level fluctuation of prefill batch
composition (running tokens/requests per engine iteration) from a live
cluster run — the fast dynamics that defeat window-based control.
"""
from __future__ import annotations

import numpy as np

from repro.serving.workload import azure_like

from benchmarks.common import serve_once, write_csv


def run(out_dir=None):
    rows = []
    # Fig. 2: 24h trace, hourly token demand per class
    reqs = azure_like(1.0, 86_400.0, seed=4)
    hours = np.zeros((24, 4))  # conv_prefill, code_prefill, conv_dec, code_dec
    for r in reqs:
        h = int(r.arrival_s // 3600) % 24
        if r.kind == "code":
            hours[h, 1] += r.prompt_len
            hours[h, 3] += r.decode_len
        else:
            hours[h, 0] += r.prompt_len
            hours[h, 2] += r.decode_len
    for h in range(24):
        rows.append({
            "fig": "fig2", "hour": h,
            "conv_prefill_tok": int(hours[h, 0]),
            "code_prefill_tok": int(hours[h, 1]),
            "conv_decode_tok": int(hours[h, 2]),
            "code_decode_tok": int(hours[h, 3]),
        })
    # the paper's claim: decode demand varies much less than prefill
    pre = hours[:, 0] + hours[:, 1]
    dec = hours[:, 2] + hours[:, 3]
    cv = lambda x: float(np.std(x) / (np.mean(x) + 1e-9))
    rows.append({
        "fig": "fig2-summary", "hour": -1,
        "conv_prefill_tok": round(cv(pre), 3),  # prefill CV
        "code_prefill_tok": round(cv(dec), 3),  # decode CV
        "conv_decode_tok": "prefill_cv_vs_decode_cv",
        "code_decode_tok": cv(pre) > cv(dec),
    })

    # Fig. 3: iteration-level prefill batch tokens from a live trace
    _, m, _ = serve_once(
        "llama-3.1-8b", "ecofreq-only", 20, duration=30.0,
        record_traces=True, return_metrics=True,
    )
    for e in m.instances:
        if not e.name.startswith("prefill"):
            continue
        for t, f, n in e.freq_trace:
            rows.append({
                "fig": "fig3", "hour": e.name,
                "conv_prefill_tok": round(t, 3),
                "code_prefill_tok": n,  # batched tokens this iteration
                "conv_decode_tok": round(f, 0),
                "code_decode_tok": "",
            })
    write_csv("fig2_3_workload_dynamics", rows, out_dir)
    return rows[:26]


if __name__ == "__main__":
    for r in run()[:5]:
        print(r)
