"""Fig. 19: behavior under different SLO profiles (low/medium/high =
400/40, 600/60, 800/80 ms TTFT/ITL for LLaMA-3.1-8B + ShareGPT). Looser
SLOs let VoltanaLLM trade more latency for energy.
"""
from __future__ import annotations

from benchmarks.common import serve_once, write_csv

PROFILES = {"low": (0.400, 0.040), "medium": (0.600, 0.060),
            "high": (0.800, 0.080)}


def run(out_dir=None, duration=90.0):
    rows = []
    for name, slo in PROFILES.items():
        for rps in (10, 20, 30):
            for policy, static in (
                ("voltana", None), ("static", 1005.0), ("static", 1410.0),
            ):
                r = serve_once(
                    "llama-3.1-8b", policy, rps, duration=duration,
                    static_freq=static, slo=slo,
                )
                r["slo_profile"] = name
                rows.append(r)
    write_csv("fig19_slo_profiles", rows, out_dir)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
