"""Fig. 13: decode state space (N_req, N_kv) → EcoFreq frequency regions,
with the tile-boundary "frequency cliffs" EcoRoute navigates around.
"""
from __future__ import annotations

import numpy as np

from repro.configs.registry import REGISTRY
from repro.core.ecofreq import EcoFreq
from repro.core.power import A100
from repro.core.state_space import frequency_cliffs, frequency_field

from benchmarks.common import predictor_for, write_csv


def run(out_dir=None):
    pred = predictor_for("llama-3.1-8b", A100, A100.freq_levels_2)
    ef = EcoFreq(A100.freq_levels_2, pred, slo_ttft_s=0.6, slo_itl_s=0.06)
    n_req = list(range(16, 513, 16))
    n_kv = [int(x) for x in np.linspace(2e4, 6e5, 24)]
    field = frequency_field(ef, n_req, n_kv)
    rows = []
    for i, q in enumerate(n_req):
        for j, k in enumerate(n_kv):
            rows.append({
                "n_req": q, "n_kv": k, "freq_mhz": field[i, j],
            })
    cliffs = frequency_cliffs(ef, n_kv=250 * 800, max_req=512)
    for c in cliffs:
        rows.append({
            "n_req": c[0], "n_kv": "cliff", "freq_mhz": f"{c[1]}->{c[2]}",
        })
    write_csv("fig13_state_space", rows, out_dir)
    return cliffs


if __name__ == "__main__":
    print("cliffs:", run())
