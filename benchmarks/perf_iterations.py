"""§Perf hillclimbing: hypothesis → change → re-lower → re-analyse, for
the three selected cells (see EXPERIMENTS.md §Perf for the narrative).

Cells (from the baseline roofline table):
* qwen3-moe-30b-a3b × prefill_32k — most collective-bound cell (MoE
  all-to-all + Megatron-TP all-reduces ≈ 0.88 of step time).
* phi4-mini-3.8b × decode_32k — most representative of the paper's
  technique (dense GQA decode, the EcoFreq/EcoRoute energy lever);
  memory-bound on KV + weight reads.
* jamba-v0.1-52b × long_500k — worst roofline fraction (single-stream
  decode reads the full weight shard per token).

Every iteration re-compiles the cell (proof the variant lowers/shards)
and recomputes the three roofline terms. The paper-faithful BASELINE and
the beyond-paper optimized variants are recorded as separate rows.
NOTE: spawns 512-host-device compiles — run standalone, not in the
default benchmark sweep (benchmarks.run includes its *results* via CSV).
"""
from __future__ import annotations

import json
import os

ITERATIONS = [
    # (arch, shape, label, variant, hypothesis)
    ("qwen3-moe-30b-a3b", "prefill_32k", "baseline", {},
     "BASELINE (paper-faithful mesh use: Megatron-TP + EP): collective-"
     "bound, MoE all-to-all ~190 GB/dev + TP all-reduce ~48 GB/dev."),
    ("qwen3-moe-30b-a3b", "prefill_32k", "fsdp_sp", {"mode": "fsdp_sp"},
     "Sequence parallelism + flat weight sharding: replace per-sublayer "
     "activation all-reduces (~48 GB) with per-layer weight all-gathers "
     "(~dense-params ≈ 5 GB) + K/V gathers (~6 GB). Napkin: collective "
     "241→~200 GB (-17%); MoE a2a untouched."),
    ("qwen3-moe-30b-a3b", "prefill_32k", "fsdp_sp+int8a2a",
     {"mode": "fsdp_sp", "dispatch_dtype": "int8"},
     "Quantize the MoE dispatch/combine buffers to int8: the all-to-all "
     "is pure token payload, tolerant to 8-bit (<1% output error, see "
     "tests). Napkin: a2a 190→97 GB; total ~108 GB (-55% vs baseline)."),
    ("phi4-mini-3.8b", "decode_32k", "baseline", {},
     "BASELINE: memory-bound (0.92 share): KV-cache read 2.1 GB/dev + "
     "weight read 0.5 GB/dev per step."),
    ("phi4-mini-3.8b", "decode_32k", "int8kv", {"kv_dtype": "int8"},
     "int8 KV cache (per-position/head scales): cache read halves. "
     "Napkin: memory term 3.2→~1.9 ms (-40%); accuracy cost ~4e-4 rel "
     "(validated)."),
    ("phi4-mini-3.8b", "decode_32k", "int8kv+w8",
     {"kv_dtype": "int8", "weight_dtype": "int8"},
     "ALSO int8 weights (per-channel): weight stream halves too. Napkin: "
     "memory term → ~1.6 ms; diminishing because cache dominated."),
    ("jamba-v0.1-52b", "long_500k", "baseline", {},
     "BASELINE: worst roofline fraction — batch=1 decode reads the full "
     "6.5 GB/dev weight shard per generated token (memory share 0.996)."),
    ("jamba-v0.1-52b", "long_500k", "w8", {"weight_dtype": "int8"},
     "int8 weights: the dominant weight stream halves. Napkin: memory "
     "term ~6.5→3.3 GB -> ~-49%."),
    ("jamba-v0.1-52b", "long_500k", "w8+int8kv",
     {"weight_dtype": "int8", "kv_dtype": "int8"},
     "ALSO int8 KV: jamba's 4 attn layers hold only ~17 MB/dev at this "
     "shape — expect NO measurable gain (testing the hypothesis that "
     "cache is negligible here)."),
]


def event_loop_benchmark(rate_rps: float = 6.0, duration_s: float = 60.0,
                         seed: int = 0, paged: bool = False,
                         spec: bool = False,
                         predictor_bank: dict = None,
                         breakdown: bool = False) -> dict:
    """Wall-clock the pure-Sim serving event loop on a fixed reference
    scenario (2P/2D SHAREGPT on A100) — the control-plane overhead the
    paged-KV / scheduling refactors must not regress.  Returns the dict
    ``benchmarks.run --smoke`` embeds in ``BENCH_serving.json``; the
    ``iters_per_s`` field is what ``tools/bench_gate.py`` gates on.

    Pass one ``predictor_bank`` dict across calls: the EcoPred offline
    profile dominates setup cost and is identical for every variant.

    ``breakdown=True`` additionally installs the
    :mod:`repro.serving.loopprof` wrappers and reports the per-phase
    split (schedule / select / route / dispatch / device_wait /
    metrics).  The wrappers cost a few ``perf_counter`` calls per
    iteration, so the headline ``iters_per_s`` row is measured with
    breakdown **off**."""
    import time

    from repro.configs.registry import REGISTRY
    from repro.core.power import A100
    from repro.serving import ClusterConfig, PDCluster, poisson_workload
    from repro.serving.workload import SHAREGPT

    model = REGISTRY["llama-3.1-8b"]
    reqs = poisson_workload(SHAREGPT, rate_rps, duration_s, seed=seed)
    cfg = ClusterConfig(
        model=model, chip=A100, n_prefill=2, n_decode=2,
        policy="voltana", online_adapt=False,
        predictor_bank=predictor_bank if predictor_bank is not None else {},
        seed=seed, paged=paged, spec_decode=spec,
    )
    cluster = PDCluster(cfg)
    prof = None
    if breakdown:
        from repro.serving import loopprof

        prof = loopprof.install(cluster)
    t0 = time.perf_counter()
    m = cluster.run(reqs)
    wall_s = time.perf_counter() - t0
    toks = m.output_tokens()
    iters = sum(
        e.backend.n_iters
        for e in cluster.prefill + cluster.decode + cluster.hybrid
    )
    out = {
        "paged": paged,
        "spec_decode": spec,
        "requests": len(reqs),
        "output_tokens": toks,
        "iterations": iters,
        "event_loop_wall_s": round(wall_s, 4),
        "iters_per_s": round(iters / wall_s, 1) if wall_s else None,
        "tokens_per_wall_s": round(toks / wall_s, 1) if wall_s else None,
        "energy_per_token_j": round(m.energy_per_token_j(), 6),
        "tokens_per_joule": round(m.tokens_per_joule(), 4),
        "ttft_attainment": round(m.ttft_attainment(), 4),
        "itl_attainment": round(m.itl_attainment(), 4),
        "finished_frac": round(m.finished_frac(), 4),
        "recompiles": m.recompiles,
    }
    if spec:
        out["accept_rate"] = round(m.acceptance_rate() or 0.0, 4)
        out["spec_yield"] = round(m.spec_yield() or 0.0, 4)
    if prof is not None:
        out["breakdown"] = prof.breakdown(wall_s)
    return out


def real_mesh_benchmark(tp: int = 1, rate_rps: float = 2.5,
                        duration_s: float = 30.0, seed: int = 0,
                        pipeline_depth: int = 1) -> dict:
    """Wall-clock the **real** (JAX-executing) event loop on a tp-wide
    mesh slice — the ``real_mesh_tp1`` gate row.  A reduced-model paged
    P/D cluster runs the scenario twice with one shared backend factory:
    the first pass warms every ``shared_jit`` entry point, the measured
    pass must replay compiled executables (``recompiles == 0`` is gated,
    so a mesh-keyed cache miss — e.g. the fingerprint accidentally
    including per-run state — shows up here, not on TPU pods).

    The slicer's pool is pinned to device 0 so both passes land on the
    same fingerprint regardless of host device count, and the virtual
    clock prices the same A100 scenario as the Sim rows — the
    ``energy_per_token_j`` golden pin must not drift when the mesh path
    changes.

    ``pipeline_depth`` sets each real backend's async-dispatch window
    (K ∈ {1, 2, 4} is the standing sweep in ``BENCH_serving.json``;
    the serving default stays K=1 until real hardware says otherwise)."""
    import dataclasses
    import time

    import jax

    from repro.configs.registry import REGISTRY
    from repro.core.power import A100
    from repro.models import model as Mmod
    from repro.serving import ClusterConfig, PDCluster, poisson_workload
    from repro.serving.realengine import make_real_backend_factory
    from repro.serving.workload import DatasetDist, LengthDist, attach_tokens

    model = REGISTRY["llama-3.1-8b"]
    rc = dataclasses.replace(model.reduced(), dtype="float32")
    rparams = Mmod.init_params(rc, jax.random.key(0))
    factory = make_real_backend_factory(
        rc, rparams, slots=8, max_len=128, paged=True, page_size=16,
        tp=tp, devices=jax.devices()[:tp],
        pipeline_depth=pipeline_depth,
    )
    tiny = DatasetDist(
        "tiny",
        prefill=LengthDist(24.0, 10.0, hi=60),
        decode=LengthDist(6.0, 3.0, hi=12),
    )

    def one_run():
        reqs = attach_tokens(
            poisson_workload(tiny, rate_rps, duration_s, seed=seed),
            rc.vocab_size, seed=seed + 1,
        )
        cfg = ClusterConfig(
            model=model, chip=A100, n_prefill=1, n_decode=2, tp=tp,
            policy="voltana", online_adapt=False, predictor_bank={},
            seed=seed, paged=True, kv_page_size=16,
            prefill_chunk_tokens=32, decode_max_running=8,
            noise_sigma=0.0, backend_factory=factory,
        )
        cluster = PDCluster(cfg)
        t0 = time.perf_counter()
        m = cluster.run(reqs)
        wall = time.perf_counter() - t0
        iters = sum(
            e.backend.n_iters
            for e in cluster.prefill + cluster.decode + cluster.hybrid
        )
        return m, iters, wall

    one_run()  # warm every jit entry point (compiles charge here)
    m, iters, wall_s = one_run()
    return {
        "tp": tp,
        "backend": "real",
        "pipeline_depth": pipeline_depth,
        "requests": len(m.requests),
        "output_tokens": m.output_tokens(),
        "iterations": iters,
        "event_loop_wall_s": round(wall_s, 4),
        "iters_per_s": round(iters / wall_s, 1) if wall_s else None,
        "energy_per_token_j": round(m.energy_per_token_j(), 6),
        "ttft_attainment": round(m.ttft_attainment(), 4),
        "itl_attainment": round(m.itl_attainment(), 4),
        "finished_frac": round(m.finished_frac(), 4),
        "recompiles": m.recompiles,
    }


def run(out_dir=None, results_path=None):
    """Reads perf_results.jsonl produced by `python -m benchmarks.perf_iterations`
    (standalone mode) and emits the §Perf table; returns rows."""
    from benchmarks.common import write_csv
    from benchmarks.roofline import terms_for_record

    results_path = results_path or os.path.join(
        os.path.dirname(__file__), "..", "perf_results.jsonl"
    )
    rows = []
    if not os.path.exists(results_path):
        print("no perf_results.jsonl — run "
              "`PYTHONPATH=src python -m benchmarks.perf_iterations` first")
        return rows
    recs = {}
    with open(results_path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r.get("label", "baseline"))] = r
    for arch, shape, label, variant, hypothesis in ITERATIONS:
        r = recs.get((arch, shape, label))
        if r is None or r.get("status") != "ok":
            rows.append({"arch": arch, "shape": shape, "label": label,
                         "status": "missing/fail"})
            continue
        t = terms_for_record(r)
        base = recs.get((arch, shape, "baseline"))
        tb = terms_for_record(base) if base else t
        dom = max(t, key=t.get)
        domb = max(tb, key=tb.get)
        rows.append({
            "arch": arch, "shape": shape, "label": label,
            "hypothesis": hypothesis[:90],
            "compute_s": f"{t['compute']:.3e}",
            "memory_s": f"{t['memory']:.3e}",
            "collective_s": f"{t['collective']:.3e}",
            "total_s": f"{sum(t.values()):.3e}",
            "dominant": dom,
            "dom_before_s": f"{tb[domb]:.3e}",
            "dom_delta_pct": round(
                100 * (t[domb] - tb[domb]) / tb[domb], 1
            ),
            "total_delta_pct": round(
                100 * (sum(t.values()) - sum(tb.values()))
                / sum(tb.values()), 1
            ),
        })
    write_csv("perf_iterations", rows, out_dir)
    return rows


def main():
    """Standalone: run the actual 512-device compiles for every row."""
    from repro.launch.dryrun import run_cell

    out = os.path.join(os.path.dirname(__file__), "..",
                       "perf_results.jsonl")
    with open(out, "w") as f:
        for arch, shape, label, variant, hypothesis in ITERATIONS:
            rec = run_cell(arch, shape, False, variant=variant)
            rec["label"] = label
            rec.pop("traceback", None)
            f.write(json.dumps(rec) + "\n")
            f.flush()
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
