"""Trace-replay scenario matrix + open-loop QPS sweeps with knee detection.

Two halves, both riding the scenario registry
(:mod:`repro.serving.scenarios`):

* **Conformance matrix** — every registered scenario replayed through
  the reference 2P2D cluster at pin scale (smoke, seed 0) and checked
  against its committed golden pins.  A mismatch fails the module (and
  with it ``--smoke``): the control plane changed behaviour on a
  production arrival shape.
* **Open-loop QPS sweeps** — each scenario with ``sweep_rates`` is
  clock-warped across its rate grid (length marginals untouched) and
  served by a deliberately small 1P1D fleet so the swept range actually
  crosses saturation; :func:`repro.serving.loadgen.qps_sweep` reports
  latency/attainment per rate plus the detected saturation knee.

Besides the usual CSV, writes ``results/fig_traces_replay.json`` — the
machine-readable payload ``benchmarks/run.py --smoke`` embeds as the
``trace_replay`` section of ``BENCH_serving.json`` (gated against
``BENCH_baseline.json`` by ``tools/bench_gate.py``).

    PYTHONPATH=src python -m benchmarks.run fig_traces_replay
"""
from __future__ import annotations

import json
import os

from benchmarks.common import RESULTS_DIR, write_csv
from repro.serving import PDCluster, qps_sweep, rescale_to_rps
from repro.serving.scenarios import (
    SCENARIOS,
    build_cluster_config,
    check_pins,
    run_scenario,
    scenario_summary,
)

# sweeps run on a deliberately tiny fleet so the (small) rate grids
# actually cross the saturation knee inside CI time
SWEEP_FLEET = {"n_prefill": 1, "n_decode": 1}


def _sweep(sc, bank, smoke):
    trace = sc.build(0, smoke)

    def make_requests(rps):
        return rescale_to_rps(trace, rps).to_requests(tokens=sc.tokens)

    def run_cluster(reqs):
        cfg = build_cluster_config(sc, predictor_bank=bank, **SWEEP_FLEET)
        m = PDCluster(cfg).run(reqs)
        return m

    return qps_sweep(make_requests, run_cluster, sc.sweep_rates)


def run(out_dir=None):
    smoke = os.environ.get("BENCH_SMOKE") == "1"
    bank: dict = {}
    rows = []
    payload = {"schema": 1, "scenarios": {}, "sweeps": {}}
    mismatches = []

    # -- conformance matrix (always at pin scale: smoke, seed 0) ------
    for name, sc in SCENARIOS.items():
        m, _, reqs = run_scenario(name, smoke=True, predictor_bank=bank)
        summary = scenario_summary(m)
        bad = check_pins(sc, summary)
        mismatches += bad
        payload["scenarios"][name] = {**summary, "pin_ok": not bad}
        rows.append({
            "kind": "scenario", "scenario": name, "rps": "",
            "n_requests": len(reqs), "pin_ok": int(not bad), **summary,
        })
        print(f"  {name:20s} {'ok  ' if not bad else 'PIN '}"
              f"energy/token {summary['energy_per_token_mj']:8.1f} mJ  "
              f"ttft {summary['ttft_attain']:.3f}  "
              f"itl {summary['itl_attain']:.3f}")

    # -- open-loop QPS sweeps + saturation knees ----------------------
    for name, sc in SCENARIOS.items():
        if not sc.sweep_rates:
            continue
        sweep = _sweep(sc, bank, smoke)
        payload["sweeps"][name] = sweep
        for r in sweep["rows"]:
            rows.append({"kind": "sweep", "scenario": name,
                         "pin_ok": "", **r})
        print(f"  {name:20s} sweep {sc.sweep_rates[0]:g}-"
              f"{sc.sweep_rates[-1]:g} rps: "
              f"knee {sweep['knee_rps']} rps "
              f"({sweep['knee_metric']}), attainment knee "
              f"{sweep['attainment_knee_rps']} rps")

    write_csv("fig_traces_replay", rows, out_dir)
    path = os.path.join(out_dir or RESULTS_DIR, "fig_traces_replay.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")

    if mismatches:
        raise RuntimeError(
            "golden-pin drift:\n" + "\n".join(mismatches)
        )
    return rows
