"""Fig. 20: per-iteration vs window-based frequency control (1P1D so
EcoRoute is inert). Window-based control degrades SLO attainment —
most severely for prefill, whose iteration-level load varies fastest.
"""
from __future__ import annotations

from benchmarks.common import serve_once, write_csv

INTERVALS = {"per-iteration": None, "100ms": 0.1, "1s": 1.0, "5s": 5.0}


def run(out_dir=None, duration=90.0):
    rows = []
    for label, interval in INTERVALS.items():
        for rps in (10, 20):
            r = serve_once(
                "llama-3.1-8b", "ecofreq-only", rps, duration=duration,
                control_interval_s=interval, n_prefill=1, n_decode=1,
            )
            r["control_interval"] = label
            rows.append(r)
    write_csv("fig20_control_interval", rows, out_dir)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
