"""Shared machinery for the paper-figure benchmarks.

Every benchmark module exposes ``run(out_dir) -> list[dict]`` and writes
its rows as ``<name>.csv`` under ``benchmarks/results/``. Serving
benchmarks share offline-profiled EcoPred predictors via a process-level
cache (one per (model, chip, freq-set, tp)), which is also what a real
deployment does — profile once, serve many.

Paper defaults (§VI): 2P2D, F = {1005, 1410} MHz on A100, TTFT/ITL SLOs
200/20, 600/60, 1200/120 ms for Ministral-3B / LLaMA-3.1-8B / Qwen3-32B,
ShareGPT + LMSYS workloads, Poisson arrivals.
"""
from __future__ import annotations

import csv
import os
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.registry import REGISTRY
from repro.core.power import A100, GH200, TPU_V5E, ChipSpec
from repro.serving import ClusterConfig, PDCluster, poisson_workload
from repro.serving.cluster import build_predictor
from repro.serving.workload import DATASETS

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

# paper §VI-B model setups: (slo_ttft_s, slo_itl_s, tp)
PAPER_SETUPS = {
    "ministral-3b": (0.200, 0.020, 1),
    "llama-3.1-8b": (0.600, 0.060, 1),
    "qwen3-32b": (1.200, 0.120, 2),
}

# RPS grids chosen so the static sweet-spot baseline degrades at the top
# (calibrated on the 2P2D A100 capacity curves of each model)
RPS_GRID = {
    "ministral-3b": (15, 40, 80, 130),
    "llama-3.1-8b": (6, 15, 30, 55),
    "qwen3-32b": (3, 8, 16, 28),
}

_PREDICTORS: Dict[tuple, object] = {}


def predictor_for(model_name: str, chip: ChipSpec,
                  freqs: Sequence[float], tp: int = 1):
    key = (model_name, chip.name, tuple(sorted(freqs)), tp)
    if key not in _PREDICTORS:
        _PREDICTORS[key] = build_predictor(
            REGISTRY[model_name], chip, freqs, tp=tp
        )
    return _PREDICTORS[key]


def serve_once(
    model_name: str,
    policy: str,
    rps: float,
    *,
    chip: ChipSpec = A100,
    dataset: str = "sharegpt",
    duration: float = 90.0,
    static_freq: Optional[float] = None,
    power_cap_w: Optional[float] = None,
    freq_levels: int = 2,
    freq_options: Optional[Sequence[float]] = None,
    freq_options_prefill: Optional[Sequence[float]] = None,
    control_interval_s: Optional[float] = None,
    delta: float = 500.0,
    n_prefill: int = 2,
    n_decode: int = 2,
    slo: Optional[Tuple[float, float]] = None,
    online_adapt: bool = False,
    record_traces: bool = False,
    requests=None,
    seed: int = 0,
    return_metrics: bool = False,
):
    """One serving run; returns a flat summary row (or (row, metrics))."""
    slo_p, slo_d, tp = (
        (*slo, PAPER_SETUPS.get(model_name, (0, 0, 1))[2])
        if slo is not None
        else PAPER_SETUPS.get(model_name, (0.6, 0.06, 1))
    )
    fo = tuple(
        freq_options
        or (chip.freq_levels_5 if freq_levels == 5 else chip.freq_levels_2)
    )
    all_freqs = sorted(set(fo) | set(freq_options_prefill or ()))
    pred = predictor_for(model_name, chip, all_freqs, tp)
    cfg = ClusterConfig(
        model=REGISTRY[model_name],
        chip=chip,
        n_prefill=n_prefill,
        n_decode=n_decode,
        tp=tp,
        slo_ttft_s=slo_p,
        slo_itl_s=slo_d,
        policy=policy,
        static_freq=static_freq,
        power_cap_w=power_cap_w,
        freq_options=fo,
        freq_options_prefill=freq_options_prefill,
        control_interval_s=control_interval_s,
        delta=delta,
        predictor=pred,
        online_adapt=online_adapt,
        record_traces=record_traces,
        seed=seed,
    )
    reqs = requests if requests is not None else poisson_workload(
        DATASETS[dataset], rps, duration, seed=seed
    )
    cluster = PDCluster(cfg)
    m = cluster.run(reqs)
    label = policy
    if policy == "static":
        label = f"static-{static_freq:.0f}"
    if policy == "powercap":
        label = f"powercap-{power_cap_w:.0f}W"
    row = {
        "model": model_name,
        "chip": chip.name,
        "dataset": dataset,
        "policy": label,
        "rps": rps,
        **m.summary(),
    }
    if return_metrics:
        return row, m, cluster
    return row


def write_csv(name: str, rows: List[dict], out_dir: Optional[str] = None):
    out_dir = out_dir or RESULTS_DIR
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{name}.csv")
    if not rows:
        return path
    keys = []
    for r in rows:
        for k in r:
            if k not in keys:
                keys.append(k)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        w.writerows(rows)
    return path
