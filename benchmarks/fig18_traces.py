"""Fig. 18 (+ Appx. L Fig. 31): real-time frequency/batch traces of P/D
instances under EcoFreq at low vs high RPS, and round-robin vs EcoRoute
batch-size traces showing one instance held below the tile boundary.
"""
from __future__ import annotations

from benchmarks.common import serve_once, write_csv


def run(out_dir=None, duration=60.0):
    rows = []
    for rps in (4, 30):
        _, m, cluster = serve_once(
            "llama-3.1-8b", "ecofreq-only", rps, duration=duration,
            record_traces=True, return_metrics=True,
        )
        for e in m.instances:
            for (t, f, n) in e.freq_trace[::5]:
                rows.append({
                    "rps": rps, "instance": e.name,
                    "t_s": round(t, 2), "freq_mhz": round(f, 0),
                    "batch": n, "policy": "ecofreq-only",
                })
    # Appx. L: round-robin vs EcoRoute decode batch traces at high load
    for policy in ("ecofreq-only", "voltana"):
        _, m, cluster = serve_once(
            "llama-3.1-8b", policy, 30, duration=duration,
            record_traces=True, return_metrics=True,
        )
        for e in m.instances:
            if not e.name.startswith("decode"):
                continue
            for (t, f, n) in e.freq_trace[::5]:
                rows.append({
                    "rps": 30, "instance": e.name,
                    "t_s": round(t, 2), "freq_mhz": round(f, 0),
                    "batch": n, "policy": policy,
                })
    write_csv("fig18_31_traces", rows, out_dir)
    return rows[:5]


if __name__ == "__main__":
    run()
    print("traces written")
