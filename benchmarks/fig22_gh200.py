"""Fig. 22 (+ Appx. M): hardware generalization — GH200 with Qwen3-32B
(no TP), phase-specific frequency options F_P={1095,1980},
F_D={1395,1980}, vs SGLang-1980 and SGLang-Sweet (per-phase static
sweet spots).
"""
from __future__ import annotations

from repro.configs.registry import REGISTRY
from repro.core.hwmodel import HardwareModel, sweet_spot
from repro.core.power import GH200

from benchmarks.common import serve_once, write_csv

F_P = (1095.0, 1980.0)
F_D = (1395.0, 1980.0)


def run(out_dir=None, duration=90.0):
    rows = []
    # Appx. M curve summary: per-phase sweet spots on GH200
    hw = HardwareModel(REGISTRY["qwen3-32b"], GH200)
    rows.append({
        "model": "qwen3-32b", "policy": "sweet-spots", "rps": 0,
        "prefill_sweet_mhz": round(
            sweet_spot(hw, "prefill", n_tok=4096, avg_ctx=1024), 0),
        "decode_sweet_mhz": round(
            sweet_spot(hw, "decode", n_req=64, n_kv=64000), 0),
    })
    slo = (1.200, 0.120)
    for rps in (2, 5, 10, 16):
        rows.append(serve_once(
            "qwen3-32b", "voltana", rps, chip=GH200, duration=duration,
            freq_options=F_D, freq_options_prefill=F_P, slo=slo,
        ))
        rows.append(serve_once(
            "qwen3-32b", "static", rps, chip=GH200, duration=duration,
            static_freq=1980.0, slo=slo,
        ))
        # SGLang-Sweet: per-phase static sweet spots
        rows.append(serve_once(
            "qwen3-32b", "static", rps, chip=GH200, duration=duration,
            static_freq=1395.0, slo=slo,
        ))
    write_csv("fig22_gh200", rows, out_dir)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
