"""Fig. 1 + Fig. 5: U-shaped energy-frequency curves and monotone
latency-frequency curves, per phase (LLaMA-3.1-8B on A100).

Validates the paper's anchors:
* both phases have an interior energy sweet spot at ~1005 MHz;
* frequencies below the sweet spot are strictly worse (both E and T up);
* decode 1005→1410 MHz: ≈20% ITL reduction for ≈50% more energy;
* prefill hits the TDP wall near 1305 MHz (f_eff < f_req).
"""
from __future__ import annotations

from repro.configs.registry import REGISTRY
from repro.core.hwmodel import HardwareModel, energy_frequency_curve, sweet_spot
from repro.core.power import A100

from benchmarks.common import write_csv


def run(out_dir=None):
    hw = HardwareModel(REGISTRY["llama-3.1-8b"], A100)
    rows = []
    states = {
        "prefill": dict(n_tok=4096, avg_ctx=1024),
        "decode": dict(n_req=64, n_kv=64 * 1000),
    }
    for phase, st in states.items():
        for f, t, e in energy_frequency_curve(hw, phase, n_grid=40, **st):
            c = (
                hw.prefill_iter(st["n_tok"], st["avg_ctx"], f)
                if phase == "prefill"
                else hw.decode_iter(st["n_req"], st["n_kv"], f)
            )
            rows.append({
                "phase": phase, "freq_mhz": round(f, 1),
                "f_effective_mhz": round(c.f_effective, 1),
                "latency_ms": round(t * 1e3, 3),
                "energy_j": round(e, 4),
                "power_w": round(c.power_w, 1),
            })
    # anchor summary
    d_lo = hw.decode_iter(64, 64000, 1005.0)
    d_hi = hw.decode_iter(64, 64000, 1410.0)
    p_hi = hw.prefill_iter(4096, 1024, 1410.0)
    rows.append({
        "phase": "anchors",
        "freq_mhz": 0,
        "f_effective_mhz": round(p_hi.f_effective, 1),
        "latency_ms": round(d_hi.time_s / d_lo.time_s, 3),  # ITL ratio
        "energy_j": round(d_hi.energy_j / d_lo.energy_j, 3),  # E ratio
        "power_w": round(
            sweet_spot(hw, "decode", n_req=64, n_kv=64000), 1
        ),  # sweet spot
    })
    write_csv("fig1_5_ucurve", rows, out_dir)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
