"""Appx. F (Fig. 34): TTFT and ITL CDFs at the lowest and highest request
rates. Expected shape: at low RPS VoltanaLLM's CDF tracks SGLang-1005
(low frequency suffices); at high RPS it tracks SGLang-1410 (boosting).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import RPS_GRID, serve_once, write_csv


def run(out_dir=None, duration=60.0):
    rows = []
    grid = RPS_GRID["llama-3.1-8b"]
    for rps in (grid[0], grid[-2]):
        for policy, static in (
            ("voltana", None), ("static", 1005.0), ("static", 1410.0),
        ):
            row, m, _ = serve_once(
                "llama-3.1-8b", policy, rps, duration=duration,
                static_freq=static, return_metrics=True,
            )
            for metric in ("ttft", "itl"):
                xs, qs = m.cdf(metric, points=25)
                for x, q in zip(xs, qs):
                    rows.append({
                        "rps": rps, "policy": row["policy"],
                        "metric": metric,
                        "latency_ms": round(float(x) * 1e3, 2),
                        "quantile": round(float(q), 3),
                    })
    write_csv("fig34_cdfs", rows, out_dir)
    return rows[:5]


if __name__ == "__main__":
    run()
    print("fig34 written")
