"""Fig. 17 (+ Fig. 28 / Appx. I): per-component ablation — EcoFreq-only
vs full VoltanaLLM (EcoFreq + EcoRoute), with per-phase energy split.
EcoRoute's extra saving is decode-specific.
"""
from __future__ import annotations

from benchmarks.common import RPS_GRID, serve_once, write_csv


def run(out_dir=None, duration=90.0):
    rows = []
    for rps in RPS_GRID["llama-3.1-8b"]:
        for policy, static in (
            ("static", 1410.0),
            ("ecofreq-only", None),
            ("voltana", None),
        ):
            row, m, _ = serve_once(
                "llama-3.1-8b", policy, rps, duration=duration,
                static_freq=static, return_metrics=True,
            )
            phases = m.energy_by_phase()
            row["prefill_j"] = round(phases.get("prefill", 0.0), 1)
            row["decode_j"] = round(phases.get("decode", 0.0), 1)
            rows.append(row)
    # per-phase savings vs the static-1410 row at the same RPS (Fig. 28)
    by_rps = {}
    for r in rows:
        by_rps.setdefault(r["rps"], {})[r["policy"]] = r
    for rps, d in by_rps.items():
        base = d.get("static-1410")
        for name in ("ecofreq-only", "voltana"):
            if name in d and base:
                d[name]["prefill_save_pct"] = round(
                    100 * (1 - d[name]["prefill_j"] / base["prefill_j"]), 1
                )
                d[name]["decode_save_pct"] = round(
                    100 * (1 - d[name]["decode_j"] / base["decode_j"]), 1
                )
    write_csv("fig17_ablation", rows, out_dir)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
