"""Appx. J/K (Fig. 29/30): 2-level vs 5-level frequency options, and the
Δ imbalance-threshold sensitivity {110, 210, 310, 410} under 5 levels.
"""
from __future__ import annotations

from benchmarks.common import serve_once, write_csv


def run(out_dir=None, duration=90.0):
    rows = []
    for rps in (10, 20, 30):
        for levels in (2, 5):
            r = serve_once(
                "llama-3.1-8b", "voltana", rps, duration=duration,
                freq_levels=levels,
            )
            r["levels"] = levels
            r["delta"] = 500
            rows.append(r)
        for delta in (110.0, 210.0, 310.0, 410.0):
            r = serve_once(
                "llama-3.1-8b", "voltana", rps, duration=duration,
                freq_levels=5, delta=delta,
            )
            r["levels"] = 5
            r["delta"] = delta
            rows.append(r)
    write_csv("fig29_30_levels_delta", rows, out_dir)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
