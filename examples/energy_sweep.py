"""Energy/latency sweep: reproduce the paper's core plots from the
calibrated hardware model — the per-phase U-curves (Fig. 5), the
batch-size staircase (Fig. 6), and a policy comparison across request
rates (Fig. 16's shape) — as terminal tables.

    PYTHONPATH=src python examples/energy_sweep.py
"""
import warnings

warnings.filterwarnings("ignore")

from repro.configs.registry import REGISTRY
from repro.core import A100, HardwareModel
from repro.serving import ClusterConfig, PDCluster, poisson_workload, SHAREGPT
from repro.serving.cluster import build_predictor


def main():
    model = REGISTRY["llama-3.1-8b"]
    hw = HardwareModel(model, A100)

    print("== per-phase energy/latency vs frequency (Fig. 5) ==")
    print(f"{'MHz':>6s} | {'prefill ms':>10s} {'prefill J':>10s} | "
          f"{'decode ms':>10s} {'decode J':>10s}")
    for f in (700, 900, 1005, 1100, 1200, 1305, 1410):
        p = hw.prefill_iter(4096, 1024, float(f))
        d = hw.decode_iter(64, 64_000, float(f))
        print(f"{f:6d} | {p.time_s*1e3:10.1f} {p.energy_j:10.2f} | "
              f"{d.time_s*1e3:10.2f} {d.energy_j:10.3f}")

    print("\n== decode staircase at the 256-tile boundary (Fig. 6) ==")
    for bs in (248, 252, 256, 257, 260, 264):
        c = hw.decode_iter(bs, bs * 800, 1410.0)
        print(f"batch {bs:4d}: ITL {c.time_s*1e3:6.2f} ms   "
              f"EPOT {c.energy_j/bs*1e3:6.3f} mJ")

    print("\n== policies across request rates (Fig. 16 shape) ==")
    pred = build_predictor(model, A100, A100.freq_levels_2, kv_cap=400_000)
    print(f"{'rps':>4s} {'policy':12s} {'ttft':>6s} {'itl':>6s} "
          f"{'energy J':>9s}")
    for rps in (6, 15, 30, 55):
        for policy, static in (
            ("voltana", None), ("static", 1005.0), ("static", 1410.0),
        ):
            cfg = ClusterConfig(
                model=model, chip=A100, policy=policy, static_freq=static,
                predictor=pred, kv_capacity_tokens=400_000,
                online_adapt=False, seed=1,
            )
            reqs = poisson_workload(SHAREGPT, rps, 45.0, seed=5)
            s = PDCluster(cfg).run(reqs).summary()
            name = policy if static is None else f"static-{static:.0f}"
            print(f"{rps:4d} {name:12s} {s['ttft_attain']:6.3f} "
                  f"{s['itl_attain']:6.3f} {s['energy_j']:9.0f}")


if __name__ == "__main__":
    main()
