"""End-to-end driver: REAL JAX model serving through the full P/D
disaggregated stack — continuous batching, KV migration, EcoFreq
per-iteration frequency control, EcoRoute state-space routing, a decode
instance failure with automatic re-prefill, and elastic scale-out.

Tokens are produced by actual ``prefill``/``decode_step`` forwards of a
reduced LLaMA-style model; the virtual clock/energy come from the
roofline-calibrated hardware model (CPU wall time has no TPU meaning).

    PYTHONPATH=src python examples/serve_pd_disaggregated.py
"""
import warnings

warnings.filterwarnings("ignore")

import dataclasses

import jax

from repro.configs.registry import REGISTRY
from repro.core.power import A100
from repro.models import model as M
from repro.serving import ClusterConfig, PDCluster, poisson_workload
from repro.serving.cluster import build_predictor
from repro.serving.realengine import make_real_backend_factory
from repro.serving.workload import DatasetDist, LengthDist, attach_tokens


def main():
    base = REGISTRY["llama-3.1-8b"]
    rc = dataclasses.replace(base.reduced(), dtype="float32")
    params = M.init_params(rc, jax.random.key(0))
    print(f"reduced model: {sum(x.size for x in jax.tree.leaves(params)):,} "
          "params (llama-family)")

    pred = build_predictor(base, A100, A100.freq_levels_2, kv_cap=400_000)
    tiny = DatasetDist(
        "demo",
        prefill=LengthDist(24.0, 10.0, hi=100),
        decode=LengthDist(10.0, 5.0, hi=20),
    )
    reqs = attach_tokens(
        poisson_workload(tiny, 2.5, 16.0, seed=1), rc.vocab_size, seed=2
    )
    cfg = ClusterConfig(
        model=base, chip=A100, n_prefill=1, n_decode=2,
        policy="voltana", predictor=pred, kv_capacity_tokens=400_000,
        online_adapt=False, decode_max_running=8, seed=0,
        backend_factory=make_real_backend_factory(
            rc, params, slots=8, max_len=256
        ),
    )
    cluster = PDCluster(cfg)
    cluster.schedule_failure(8.0, "decode", 0)  # chaos: kill an instance
    cluster.schedule_scale_out(8.5, "decode")  # elastic replacement
    m = cluster.run(reqs)

    s = m.summary()
    restarted = sum(1 for r in reqs if r.restarts)
    print(f"\nserved {len(reqs)} requests, finished "
          f"{s['finished_frac']:.0%}; TTFT attain {s['ttft_attain']:.2f}, "
          f"ITL attain {s['itl_attain']:.2f}")
    print(f"decode instance 0 failed at t=8 s -> {restarted} requests "
          f"re-prefilled; fleet scaled to {len(cluster.decode)} decode "
          "instances")
    print(f"modeled energy: {s['energy_j']:.0f} J "
          f"({s['epot_mj']:.1f} mJ/token)")
    done = [r for r in reqs if r.finished][:3]
    for r in done:
        print(f"req {r.rid}: prompt[{r.prompt_len}] -> "
              f"tokens {r.output_tokens[:8]}...")


if __name__ == "__main__":
    main()
