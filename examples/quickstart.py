"""Quickstart: the VoltanaLLM control plane in 60 seconds.

Builds the offline-profiled latency predictor (EcoPred), shows EcoFreq's
per-iteration frequency decisions across load levels, shows an EcoRoute
what-if routing decision near a tile boundary, then runs a short P/D
disaggregated serving simulation and prints SLO + energy vs the static
max-frequency baseline.

    PYTHONPATH=src python examples/quickstart.py
"""
import warnings

warnings.filterwarnings("ignore")

from repro.configs.registry import REGISTRY
from repro.core import (
    A100,
    BatchInfo,
    EcoFreq,
    EcoRoute,
    HardwareModel,
    InstanceView,
    RouteRequest,
    SystemState,
    sweet_spot,
)
from repro.serving import ClusterConfig, PDCluster, poisson_workload, SHAREGPT
from repro.serving.cluster import build_predictor


def main():
    model = REGISTRY["llama-3.1-8b"]
    hw = HardwareModel(model, A100)

    print("== the U-curve (paper Fig. 1) ==")
    f_star = sweet_spot(hw, "decode", n_req=64, n_kv=64_000)
    print(f"decode energy sweet spot: {f_star:.0f} MHz (paper: 1005 MHz)")

    print("\n== EcoPred + EcoFreq (Alg. 1) ==")
    pred = build_predictor(model, A100, A100.freq_levels_2, kv_cap=400_000)
    ef = EcoFreq(A100.freq_levels_2, pred, slo_ttft_s=0.6, slo_itl_s=0.06)
    for n_req, n_kv in ((8, 6_000), (128, 96_000), (400, 320_000)):
        f = ef.select(SystemState(),
                      BatchInfo("decode", n_req=n_req, n_kv=n_kv))
        t = pred.predict_decode(f, n_req, n_kv)[0] * 1e3
        print(f"decode batch {n_req:4d} ({n_kv:7d} kv) -> {f:6.0f} MHz "
              f"(predicted ITL {t:5.1f} ms vs SLO 60 ms)")
    print("waiting queue ->",
          ef.select(SystemState(has_waiting=True),
                    BatchInfo("decode", n_req=8, n_kv=6_000)), "MHz")

    print("\n== EcoRoute what-if (Alg. 2) ==")
    er = EcoRoute(ef, delta=500.0)
    # find the learned cliff, then put instance 0 right at its edge
    from repro.core.state_space import frequency_cliffs

    cliff = frequency_cliffs(ef, n_kv=250 * 600, max_req=400)
    edge = cliff[0][0] - 1 if cliff else 255
    views = [InstanceView(0, edge, edge * 600),
             InstanceView(1, edge - 40, (edge - 40) * 600)]
    pick = er.route(views, RouteRequest(prompt_len=600))
    print(f"instances at N_req = {edge} / {edge-40}, cliff at "
          f"{edge+1} -> route to instance {pick} "
          "(don't push #0 over the frequency cliff)")

    print("\n== 60 s serving simulation (2P2D, ShareGPT, 15 RPS) ==")
    reqs = poisson_workload(SHAREGPT, 15.0, 60.0, seed=0)
    rows = {}
    for policy, static in (("voltana", None), ("static", 1410.0)):
        cfg = ClusterConfig(
            model=model, chip=A100, policy=policy, static_freq=static,
            predictor=pred, kv_capacity_tokens=400_000, online_adapt=False,
        )
        rows[policy] = PDCluster(cfg).run(list(reqs)).summary()
    for k, s in rows.items():
        print(f"{k:10s} ttft {s['ttft_attain']:.3f}  itl "
              f"{s['itl_attain']:.3f}  energy {s['energy_j']:8.0f} J")
    save = 1 - rows["voltana"]["energy_j"] / rows["static"]["energy_j"]
    print(f"\nVoltanaLLM saves {save:.1%} energy at matched SLO attainment")


if __name__ == "__main__":
    main()
