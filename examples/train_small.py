"""Train a ~100M-param LLaMA-style model with the full training substrate:
WSD schedule, remat, microbatch grad accumulation, async checkpointing
with retention, and restart-from-checkpoint.

    PYTHONPATH=src python examples/train_small.py            # quick demo
    PYTHONPATH=src python examples/train_small.py --steps 300 --full-size

Kill it mid-run and re-run with the same --ckpt-dir: it resumes.
"""
import warnings

warnings.filterwarnings("ignore")

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import model as M
from repro.training import (
    AdamWConfig,
    CheckpointManager,
    TrainStepConfig,
    init_opt_state,
    make_train_step,
    wsd_schedule,
)


def model_config(full: bool) -> ModelConfig:
    if full:  # ~100M params
        return ModelConfig(
            name="demo-100m", family="dense", n_layers=10, d_model=640,
            n_heads=10, n_kv_heads=5, head_dim=64, d_ff=2560,
            vocab_size=32_000,
            block_pattern=(LayerSpec(mixer="attn", ffn="mlp"),),
            dtype="float32",
        )
    return ModelConfig(  # ~8M params: seconds-per-step on CPU
        name="demo-8m", family="dense", n_layers=4, d_model=256,
        n_heads=8, n_kv_heads=4, head_dim=32, d_ff=1024, vocab_size=8_192,
        block_pattern=(LayerSpec(mixer="attn", ffn="mlp"),),
        dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    args = ap.parse_args()

    cfg = model_config(args.full_size)
    params = M.init_params(cfg, jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params")

    opt = init_opt_state(params)
    tcfg = TrainStepConfig(
        adamw=AdamWConfig(lr=6e-4), microbatches=2,
        ce_chunk=min(128, args.seq),
    )
    sched = wsd_schedule(args.steps // 10 + 1, args.steps // 2,
                         args.steps // 2, 6e-4)
    step = jax.jit(make_train_step(cfg, tcfg, sched))

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    restored, start = mgr.restore_latest({"params": params, "opt": opt})
    if restored is not None:
        params = jax.tree.map(jnp.asarray, restored["params"])
        opt = jax.tree.map(jnp.asarray, restored["opt"])
        print(f"resumed from checkpoint at step {start}")
    else:
        start = 0

    # synthetic language-like data: zipfian tokens with local structure
    rng = np.random.default_rng(1)
    t0 = time.time()
    for i in range(start, args.steps):
        base = rng.zipf(1.5, (args.batch, args.seq)).clip(
            1, cfg.vocab_size - 1
        )
        toks = jnp.asarray(base, jnp.int32)
        labels = jnp.roll(toks, -1, 1).at[:, -1].set(-100)
        params, opt, m = step(params, opt, {"tokens": toks,
                                            "labels": labels})
        if (i + 1) % 10 == 0 or i == start:
            print(f"step {i+1:4d}/{args.steps}  loss {float(m['loss']):.4f}"
                  f"  lr {float(m['lr']):.2e}  "
                  f"({time.time()-t0:5.1f}s)")
        if (i + 1) % 25 == 0:
            mgr.save(i + 1, {"params": params, "opt": opt})
    mgr.save(args.steps, {"params": params, "opt": opt}, block=True)
    print(f"done; checkpoints retained: {mgr.steps()}")


if __name__ == "__main__":
    main()
