"""EcoScale walkthrough: heterogeneous fleets + SLO-aware autoscaling.

Three acts, all on CPU in a couple of minutes:

1. *Chip identity* — why placement should care which chip a request
   lands on: per-chip decode energy/token and prefill capacity.
2. *Phase-aware placement* — a what-if routing decision on a mixed
   A100 + GH200 decode fleet at low load (the cheap chip wins) and under
   pressure (the fast chip absorbs).
3. *Autoscaling* — a trough→peak→trough load step on a mixed fleet:
   watch EcoScale drain/park instances in the trough, re-admit them at
   the step (including the event-driven pressure wake), and compare
   energy against the same fleet pinned fully on.

    PYTHONPATH=src python examples/serve_autoscale.py
"""
import warnings

warnings.filterwarnings("ignore")

from repro.configs.registry import REGISTRY
from repro.core import (
    A100,
    GH200,
    EcoFreq,
    EnergyAwareEcoRoute,
    HardwareModel,
    InstanceProfile,
    InstanceView,
    RouteRequest,
)
from repro.serving import (
    AutoScaleConfig,
    ClusterConfig,
    InstanceSpec,
    PDCluster,
    SHAREGPT,
    step_load,
)
from repro.serving.cluster import build_predictor

MODEL = REGISTRY["llama-3.1-8b"]
GH200_D = (1395.0, 1980.0)


def act1_chip_identity():
    print("== 1. chip identity (why placement must be chip-aware) ==")
    for chip in (A100, GH200):
        hw = HardwareModel(MODEL, chip)
        print(
            f"  {chip.name:14s} decode energy/token {hw.decode_ept_j()*1e3:6.1f} mJ"
            f"   prefill capacity {hw.prefill_capacity_tok_s()/1e3:6.1f} ktok/s"
            f"   idle {hw.idle_power():3.0f} W  parked {hw.sleep_power():3.0f} W"
        )


def act2_placement(preds):
    print("\n== 2. phase-aware what-if placement (mixed decode fleet) ==")
    profiles = {
        0: InstanceProfile(
            A100,
            EcoFreq(A100.freq_levels_2, preds["a100"], 0.6, 0.06),
            HardwareModel(MODEL, A100),
        ),
        1: InstanceProfile(
            GH200,
            EcoFreq(GH200_D, preds["gh200"], 0.6, 0.06),
            HardwareModel(MODEL, GH200),
        ),
    }
    router = EnergyAwareEcoRoute(profiles, slo_itl_s=0.06)
    cold = [InstanceView(0, 0, 0), InstanceView(1, 0, 0)]
    pick = router.route(cold, RouteRequest(prompt_len=600))
    print(f"  cold fleet                      -> instance {pick} "
          f"({'A100 — cheaper to spin up' if pick == 0 else 'GH200'})")
    warm = [InstanceView(0, 8, 6_000), InstanceView(1, 0, 0)]
    pick = router.route(warm, RouteRequest(prompt_len=600))
    print(f"  A100 warm (8 reqs), GH200 idle  -> instance {pick} "
          "(consolidate: marginal J/token on a busy instance is tiny)")
    hi = [InstanceView(0, 400, 300_000), InstanceView(1, 64, 48_000)]
    pick = router.route(hi, RouteRequest(prompt_len=600))
    print(f"  A100 saturated (400 reqs)       -> instance {pick} "
          f"({'GH200 — absorbs the burst' if pick == 1 else 'A100'})")


def act3_autoscale(preds):
    print("\n== 3. autoscaling a mixed fleet through a load step ==")
    bank = {("a100-80g-sxm", 1): preds["a100"], ("gh200", 1): preds["gh200"]}
    fleet = dict(
        prefill_fleet=[
            InstanceSpec(A100),
            InstanceSpec(GH200, freq_options=(1095.0, 1980.0)),
        ],
        decode_fleet=[
            InstanceSpec(A100),
            InstanceSpec(A100),
            InstanceSpec(GH200, freq_options=GH200_D),
        ],
    )
    segments = [(60.0, 2.0), (60.0, 24.0), (60.0, 2.0)]
    rows = {}
    for label, auto in (
        ("ecoscale", AutoScaleConfig(interval_s=2.0, cooldown_s=6.0)),
        ("pinned-on", None),
    ):
        cfg = ClusterConfig(
            model=MODEL, chip=A100, policy="voltana",
            slo_ttft_s=0.6, slo_itl_s=0.06,
            online_adapt=False, predictor_bank=bank, seed=0,
            autoscale=auto, **fleet,
        )
        cluster = PDCluster(cfg)
        m = cluster.run(step_load(SHAREGPT, segments, seed=4))
        rows[label] = m
        print(f"  {label:10s} ttft {m.ttft_attainment():.3f}  "
              f"itl {m.itl_attainment():.3f}  "
              f"energy {m.energy_j():8.0f} J  parked {m.parked_s_total():6.0f} s")
        if cluster.autoscaler is not None:
            print("  autoscaler timeline:")
            for ev in cluster.autoscaler.events[:12]:
                print(f"    t={ev.t:6.1f}s  {ev.phase:8s} {ev.action:8s} "
                      f"instance {ev.idx}")
    save = 1 - rows["ecoscale"].energy_j() / rows["pinned-on"].energy_j()
    print(f"\n  EcoScale saves {save:.1%} energy vs the always-on fleet "
          "at matched SLO attainment")


def main():
    print("building per-chip EcoPred predictors (one-off, ~30 s) ...")
    preds = {
        "a100": build_predictor(
            MODEL, A100, A100.freq_levels_2, kv_cap=400_000
        ),
        "gh200": build_predictor(
            MODEL, GH200, sorted({1095.0, 1395.0, 1980.0}), kv_cap=400_000
        ),
    }
    act1_chip_identity()
    act2_placement(preds)
    act3_autoscale(preds)


if __name__ == "__main__":
    main()
